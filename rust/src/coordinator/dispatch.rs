//! Live block-wise dataflow (paper §III-C), with real compute.
//!
//! This is the paper's proposed runtime organization actually running:
//!
//! * a **memory controller** owns the work queue of (patch, block-row)
//!   items — "send work to the next available block";
//! * each physical **block instance** is a worker thread holding its
//!   programmed crossbar rows ([`crate::xbar::SubArray`]); it pulls an
//!   item, computes the partial dot product, and sends the packetized
//!   partial sums (tagged with the destination-accumulator id carried in
//!   the input packet, §III-C) to the vector unit;
//! * the **vector unit** thread gathers partial sums per output
//!   position; when all block rows of a patch have reported, the
//!   accumulated result is committed to the output feature map.
//!
//! The committed OFM is verified against the reference convolution —
//! demonstrating that relaxing the gather/accumulate pairing (the whole
//! point of the block-wise dataflow) preserves functional correctness.

use crate::config::ArrayCfg;
use crate::tensor::{conv_ref, im2col_u8, Im2colSpec, Tensor};
use crate::xbar::{ReadMode, SubArray};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// One work item: compute block row `row`'s slice of patch `patch`.
#[derive(Debug, Clone, Copy)]
struct WorkItem {
    patch: usize,
    row: usize,
    /// Destination-accumulator address (§III-C packet header).
    accumulator: usize,
}

/// A partial-sum packet from a block instance to the vector unit.
#[derive(Debug, Clone)]
struct PsumPacket {
    patch: usize,
    row: usize,
    accumulator: usize,
    psums: Vec<i32>,
    /// Which physical instance produced this packet (telemetry).
    #[allow(dead_code)]
    worker: usize,
}

/// Result of a dispatch run.
#[derive(Debug)]
pub struct DispatchReport {
    /// Total work items executed.
    pub items: usize,
    /// Items per worker (shows dynamic balancing across duplicates).
    pub per_worker: Vec<usize>,
    /// Output feature map, `[out_ch, oh*ow]` i32 accumulations.
    pub ofm: Tensor<i32>,
    /// Did the OFM match the reference convolution exactly?
    pub verified: bool,
    /// Simulated zero-skip cycles summed per worker (busy work).
    pub busy_cycles: Vec<u64>,
}

/// Run one conv layer through the live block-wise dataflow.
///
/// `dups[r]` = physical duplicates of block row `r`; `threads` spawn one
/// worker per duplicate. Correctness does not depend on scheduling
/// order — that is the property being demonstrated.
pub fn run_conv_blockwise(
    cfg: &ArrayCfg,
    input: &Tensor<u8>,
    weights: &Tensor<i8>, // [Cout, Cin, K, K]
    stride: usize,
    pad: usize,
    dups: &[usize],
) -> crate::Result<DispatchReport> {
    let (cin, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let (cout, k) = (weights.shape()[0], weights.shape()[2]);
    let spec = Im2colSpec { in_ch: cin, in_h: h, in_w: w, k, stride, pad };
    let patches = Arc::new(im2col_u8(input, &spec));
    let plen = spec.patch_len();
    let positions = spec.positions();
    let n_rows = plen.div_ceil(cfg.rows);
    anyhow::ensure!(dups.len() == n_rows, "need one dup count per block row ({n_rows})");

    // Weight matrix in crossbar row order (CHW patches), [plen, cout].
    let mut wmat = vec![0i8; plen * cout];
    for oc in 0..cout {
        let mut r = 0;
        for ic in 0..cin {
            for ky in 0..k {
                for kx in 0..k {
                    wmat[r * cout + oc] = weights.get(&[oc, ic, ky, kx]);
                    r += 1;
                }
            }
        }
    }

    // Program one wide sub-array slice per block row. (A block is
    // arrays_per_block physical arrays sharing word lines; functionally
    // that is one matrix slice, modeled here as a SubArray with
    // `cout` 8-bit columns.)
    let mut slice_cfg = *cfg;
    slice_cfg.cols = cout * slice_cfg.weight_bits;
    let block_arrays: Vec<Arc<SubArray>> = (0..n_rows)
        .map(|r| {
            let lo = r * cfg.rows;
            let hi = ((r + 1) * cfg.rows).min(plen);
            Arc::new(SubArray::program(slice_cfg, &wmat[lo * cout..hi * cout]))
        })
        .collect();

    // Memory controller: per-block-row shared queues (workers of row r
    // pull from queue r — "request additional work from the memory
    // controller").
    let queues: Vec<Arc<Mutex<Vec<WorkItem>>>> = (0..n_rows)
        .map(|r| {
            // reversed so pop() serves patch 0 first
            let items: Vec<WorkItem> = (0..positions)
                .rev()
                .map(|p| WorkItem { patch: p, row: r, accumulator: p % 4 })
                .collect();
            Arc::new(Mutex::new(items))
        })
        .collect();

    let (psum_tx, psum_rx) = mpsc::channel::<PsumPacket>();

    // Workers: one thread per physical block instance.
    let mut handles = Vec::new();
    let mut worker_id = 0usize;
    for r in 0..n_rows {
        for _ in 0..dups[r] {
            let queue = Arc::clone(&queues[r]);
            let array = Arc::clone(&block_arrays[r]);
            let patches = Arc::clone(&patches);
            let tx = psum_tx.clone();
            let id = worker_id;
            let rows_lo = r * cfg.rows;
            let rows_hi = ((r + 1) * cfg.rows).min(plen);
            handles.push(thread::spawn(move || -> (usize, usize, u64) {
                let mut done = 0usize;
                let mut busy = 0u64;
                loop {
                    let item = { queue.lock().unwrap().pop() };
                    let Some(item) = item else { break };
                    let row_data =
                        &patches.data()[item.patch * plen + rows_lo..item.patch * plen + rows_hi];
                    let (psums, cycles) = array.matvec(row_data, ReadMode::ZeroSkip);
                    busy += cycles as u64;
                    tx.send(PsumPacket {
                        patch: item.patch,
                        row: item.row,
                        accumulator: item.accumulator,
                        psums,
                        worker: id,
                    })
                    .expect("vector unit alive");
                    done += 1;
                }
                (id, done, busy)
            }));
            worker_id += 1;
        }
    }
    drop(psum_tx);
    let n_workers = worker_id;

    // Vector unit: gather by (patch, row) until each patch has all rows.
    let vu = thread::spawn(move || -> (Tensor<i32>, usize) {
        let mut ofm: Tensor<i32> = Tensor::zeros(&[cout, positions]);
        let mut remaining = vec![n_rows; positions];
        let mut committed = 0usize;
        while let Ok(pkt) = psum_rx.recv() {
            debug_assert!(pkt.accumulator < 4);
            for (c, &v) in pkt.psums.iter().enumerate() {
                let off = c * positions + pkt.patch;
                ofm.data_mut()[off] += v;
            }
            remaining[pkt.patch] -= 1;
            if remaining[pkt.patch] == 0 {
                committed += 1;
            }
            let _ = pkt.row;
        }
        (ofm, committed)
    });

    let mut per_worker = vec![0usize; n_workers];
    let mut busy_cycles = vec![0u64; n_workers];
    for h in handles {
        let (id, done, busy) = h.join().expect("worker panicked");
        per_worker[id] = done;
        busy_cycles[id] = busy;
    }
    let (ofm, committed) = vu.join().expect("vector unit panicked");
    anyhow::ensure!(committed == positions, "only {committed}/{positions} patches completed");

    // Verify against the reference convolution.
    let reference = conv_ref::conv2d_i32(input, weights, stride, pad);
    let verified = reference.data() == ofm.data();

    Ok(DispatchReport {
        items: positions * n_rows,
        per_worker,
        ofm,
        verified,
        busy_cycles,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn setup(cin: usize, cout: usize, hw: usize, seed: u64) -> (Tensor<u8>, Tensor<i8>) {
        let mut rng = Prng::new(seed);
        let input = Tensor::from_fn(&[cin, hw, hw], |_| (rng.next_u32() as u8) & 0x3F);
        let weights = Tensor::from_fn(&[cout, cin, 3, 3], |_| rng.next_u32() as i8);
        (input, weights)
    }

    #[test]
    fn single_block_single_worker_verifies() {
        let (input, weights) = setup(4, 8, 6, 1);
        let r = run_conv_blockwise(&ArrayCfg::paper(), &input, &weights, 1, 1, &[1]).unwrap();
        assert!(r.verified, "OFM mismatch");
        assert_eq!(r.items, 36);
    }

    #[test]
    fn multi_block_multi_duplicate_verifies() {
        // 32 ch * 9 = 288 rows -> 3 block rows; uneven duplicates
        let (input, weights) = setup(32, 16, 8, 2);
        let r =
            run_conv_blockwise(&ArrayCfg::paper(), &input, &weights, 1, 1, &[3, 1, 2]).unwrap();
        assert!(r.verified, "OFM mismatch with uneven duplicates");
        assert_eq!(r.per_worker.len(), 6);
        // conservation: block 0's three workers together did all patches
        // (how the 64 items split between them is scheduling-dependent —
        // on a 2-core host one worker may drain the queue early)
        assert_eq!(r.per_worker[0] + r.per_worker[1] + r.per_worker[2], 64);
    }

    #[test]
    fn strided_conv_verifies() {
        let (input, weights) = setup(8, 8, 8, 3);
        let r = run_conv_blockwise(&ArrayCfg::paper(), &input, &weights, 2, 1, &[1]).unwrap();
        assert!(r.verified);
    }

    #[test]
    fn busy_cycles_are_plausible() {
        let (input, weights) = setup(16, 8, 6, 4);
        let r = run_conv_blockwise(&ArrayCfg::paper(), &input, &weights, 1, 1, &[2, 1]).unwrap();
        assert!(r.verified);
        let total: u64 = r.busy_cycles.iter().sum();
        // 36 patches x 2 block rows, each 0..=1024 cycles
        assert!(total > 0 && total <= 36 * 2 * 1024);
    }

    #[test]
    fn wrong_dup_count_rejected() {
        let (input, weights) = setup(4, 4, 6, 5);
        assert!(run_conv_blockwise(&ArrayCfg::paper(), &input, &weights, 1, 1, &[1, 1]).is_err());
    }
}
