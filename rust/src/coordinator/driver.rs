//! End-to-end driver: profile → allocate → simulate → report.

use crate::alloc::{allocate, Algorithm};
use crate::config::{ArrayCfg, ChipCfg};
use crate::dnn::{resnet18, vgg11, Graph};
use crate::mapping::{map_network, place, AllocationPlan, NetworkMap};
use crate::runtime::{Engine, GoldenModel, Manifest};
use crate::sim::{simulate, SimCfg, SimResult};
use crate::stats::synth::{synth_activations, SynthCfg};
use crate::stats::{trace_from_activations, NetTrace, NetworkProfile};
use anyhow::Result;

/// Where activation statistics come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsSource {
    /// Synthetic generator (no artifacts needed; benches use this).
    Synthetic,
    /// The AOT-exported quantized model executed over PJRT — real
    /// activations of the real (randomly-initialized) network.
    Golden,
}

impl StatsSource {
    pub fn parse(s: &str) -> Option<StatsSource> {
        match s {
            "synth" | "synthetic" => Some(StatsSource::Synthetic),
            "golden" | "pjrt" => Some(StatsSource::Golden),
            _ => None,
        }
    }
}

/// Driver configuration.
#[derive(Debug, Clone)]
pub struct DriverOpts {
    pub net: String,
    /// Input resolution (must match the artifact when `Golden`).
    pub hw: usize,
    pub stats: StatsSource,
    /// Images used for profiling statistics.
    pub profile_images: usize,
    /// Images pushed through the pipelined simulation.
    pub sim_images: usize,
    pub seed: u64,
    pub artifacts_dir: String,
}

impl Default for DriverOpts {
    fn default() -> Self {
        DriverOpts {
            net: "resnet18".into(),
            hw: 64,
            stats: StatsSource::Synthetic,
            profile_images: 2,
            sim_images: 8,
            seed: 7,
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// A fully prepared experiment: everything up to (but excluding) the
/// allocation/simulation choices.
pub struct Driver {
    pub opts: DriverOpts,
    pub graph: Graph,
    pub map: NetworkMap,
    pub trace: NetTrace,
    pub profile: NetworkProfile,
}

impl Driver {
    /// Build the graph, gather statistics, derive the profile.
    pub fn prepare(opts: DriverOpts) -> Result<Driver> {
        let graph = build_graph(&opts.net, opts.hw)?;
        graph.validate().map_err(|e| anyhow::anyhow!(e))?;
        let map = map_network(&graph, ArrayCfg::paper(), false);
        let acts = match opts.stats {
            StatsSource::Synthetic => {
                synth_activations(&graph, &map, opts.profile_images, opts.seed, SynthCfg::default())
            }
            StatsSource::Golden => {
                let manifest = Manifest::load(&opts.artifacts_dir)?;
                let engine = Engine::cpu()?;
                let model = GoldenModel::load(&engine, &manifest, &opts.net)?;
                anyhow::ensure!(
                    model.meta.hw == opts.hw,
                    "artifact exported at hw={}, requested {} — re-run `make artifacts` \
                     with --hw or adjust --hw",
                    model.meta.hw,
                    opts.hw
                );
                model.profile(opts.profile_images, opts.seed)?
            }
        };
        let trace = trace_from_activations(&graph, &map, &acts);
        let profile = NetworkProfile::from_trace(&map, &trace);
        Ok(Driver { opts, graph, map, trace, profile })
    }

    /// Allocate + place + simulate one algorithm on a chip of `pes` PEs.
    pub fn run(&self, alg: Algorithm, pes: usize) -> Result<(AllocationPlan, SimResult)> {
        let chip = ChipCfg::paper(pes);
        let plan = allocate(alg, &self.map, &self.profile, chip.total_arrays())?;
        let placement = place(&self.map, &plan, &chip)?;
        let cfg = SimCfg::for_algorithm(alg, self.opts.sim_images);
        let result = simulate(&chip, &self.map, &plan, &placement, &self.trace, cfg);
        Ok((plan, result))
    }

    /// Run all four paper algorithms at one design size.
    pub fn run_all(&self, pes: usize) -> Result<Vec<(Algorithm, SimResult)>> {
        Algorithm::all()
            .into_iter()
            .map(|alg| Ok((alg, self.run(alg, pes)?.1)))
            .collect()
    }

    /// Minimum PEs that fit one copy of the network (paper: 86 for
    /// ResNet18).
    pub fn min_pes(&self) -> usize {
        let per_pe = ChipCfg::paper(1).arrays_per_pe;
        self.map.min_arrays().div_ceil(per_pe)
    }

    /// The paper's design-size sweep: half-powers of two from the
    /// minimum (§V: "we begin increasing the design size by ½ powers
    /// of 2").
    pub fn sweep_sizes(&self, steps: usize) -> Vec<usize> {
        let min = self.min_pes();
        (0..steps)
            .map(|i| ((min as f64) * 2f64.powf(i as f64 / 2.0)).round() as usize)
            .collect()
    }
}

fn build_graph(net: &str, hw: usize) -> Result<Graph> {
    match net {
        "resnet18" => Ok(resnet18(hw, 1000)),
        "resnet34" => Ok(crate::dnn::resnet34(hw, 1000)),
        "vgg11" => Ok(vgg11(hw, 10)),
        other => anyhow::bail!("unknown network '{other}' (resnet18|resnet34|vgg11)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_driver(net: &str) -> Driver {
        Driver::prepare(DriverOpts {
            net: net.into(),
            hw: 32,
            profile_images: 1,
            sim_images: 4,
            ..DriverOpts::default()
        })
        .unwrap()
    }

    #[test]
    fn resnet18_min_pes_matches_paper() {
        let d = synth_driver("resnet18");
        assert_eq!(d.min_pes(), 86); // §V
    }

    #[test]
    fn sweep_sizes_half_powers() {
        let d = synth_driver("resnet18");
        let sizes = d.sweep_sizes(5);
        assert_eq!(sizes[0], 86);
        assert_eq!(sizes[2], 172);
        assert_eq!(sizes[4], 344);
        assert!((sizes[1] as f64 - 86.0 * 2f64.sqrt()).abs() < 1.0);
    }

    #[test]
    fn run_all_produces_ordered_speedups() {
        let d = synth_driver("resnet18");
        let results = d.run_all(172).unwrap();
        let get = |alg: Algorithm| {
            results.iter().find(|(a, _)| *a == alg).unwrap().1.throughput_ips
        };
        assert!(get(Algorithm::BlockWise) >= get(Algorithm::PerfBased));
        assert!(get(Algorithm::PerfBased) >= get(Algorithm::WeightBased) * 0.95);
        assert!(get(Algorithm::WeightBased) > get(Algorithm::Baseline));
    }

    #[test]
    fn vgg11_driver_works() {
        let d = synth_driver("vgg11");
        let (plan, result) = d.run(Algorithm::BlockWise, d.min_pes() * 2).unwrap();
        plan.validate(&d.map, ChipCfg::paper(d.min_pes() * 2).total_arrays()).unwrap();
        assert!(result.throughput_ips > 0.0);
    }

    #[test]
    fn unknown_net_rejected() {
        assert!(Driver::prepare(DriverOpts { net: "alexnet".into(), ..DriverOpts::default() })
            .is_err());
    }
}
