//! End-to-end driver: a thin convenience wrapper over the staged
//! experiment pipeline ([`crate::pipeline`]).
//!
//! `Driver::prepare` runs the pipeline's shared prefix stages
//! (`BuildGraph → Map → Stats → Trace → Profile`) for one [`DriverOpts`];
//! `Driver::run_strategy` executes the scenario stages (`Allocate →
//! Place → Simulate`) for one registry strategy × design size. Sweeps
//! over many scenarios should use [`crate::pipeline::run_sweep`]
//! directly — it shares the prepared prefix across scenarios and runs
//! them on a worker pool.

use crate::mapping::AllocationPlan;
use crate::pipeline::{self, PrefixSpec, PreparedView, Scenario, ScenarioBuilder};
use crate::sim::SimResult;
use crate::strategy::{StrategyRegistry, PAPER_ALGORITHMS};
use anyhow::Result;

pub use crate::pipeline::StatsSource;

/// Driver configuration.
#[derive(Debug, Clone)]
pub struct DriverOpts {
    /// Network name (one of [`crate::pipeline::KNOWN_NETS`]).
    pub net: String,
    /// Input resolution — the CLI's `--res` (must match the artifact
    /// when `Golden`). Not the hardware profile; that is `hw_profile`.
    pub hw: usize,
    /// Hardware profile name/alias or profile-JSON path
    /// ([`crate::hw::ProfileRegistry::resolve`]).
    pub hw_profile: String,
    /// Where activation statistics come from.
    pub stats: StatsSource,
    /// Images used for profiling statistics.
    pub profile_images: usize,
    /// Images pushed through the pipelined simulation.
    pub sim_images: usize,
    /// Deterministic seed for synthetic statistics.
    pub seed: u64,
    /// Where the AOT artifacts live (used only with `Golden`).
    pub artifacts_dir: String,
}

impl Default for DriverOpts {
    fn default() -> Self {
        DriverOpts {
            net: "resnet18".into(),
            hw: 64,
            hw_profile: crate::hw::DEFAULT_PROFILE.into(),
            stats: StatsSource::Synthetic,
            profile_images: 2,
            sim_images: 8,
            seed: 7,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl DriverOpts {
    /// The pipeline prefix these options describe.
    pub fn prefix_spec(&self) -> PrefixSpec {
        PrefixSpec {
            net: self.net.clone(),
            hw: self.hw,
            hw_profile: self.hw_profile.clone(),
            stats: self.stats,
            profile_images: self.profile_images,
            seed: self.seed,
            artifacts_dir: self.artifacts_dir.clone(),
        }
    }
}

/// A fully prepared experiment: everything up to (but excluding) the
/// allocation/simulation choices.
pub struct Driver {
    /// The options this driver was prepared with.
    pub opts: DriverOpts,
    /// The resolved hardware profile everything below was built with.
    pub hw: crate::hw::HwProfile,
    /// The validated network graph.
    pub graph: crate::dnn::Graph,
    /// The mapped network.
    pub map: crate::mapping::NetworkMap,
    /// The exact cycle trace.
    pub trace: crate::stats::NetTrace,
    /// The aggregate profile the allocators consume.
    pub profile: crate::stats::NetworkProfile,
}

impl Driver {
    /// Run the pipeline prefix stages: build the graph, gather
    /// statistics, derive the profile.
    pub fn prepare(opts: DriverOpts) -> Result<Driver> {
        let prep = pipeline::prepare(&opts.prefix_spec(), None)?;
        Ok(Driver {
            opts,
            hw: prep.hw,
            graph: prep.graph,
            map: prep.map,
            trace: prep.trace,
            profile: prep.profile,
        })
    }

    fn view(&self) -> PreparedView<'_> {
        PreparedView { hw: &self.hw, map: &self.map, trace: &self.trace, profile: &self.profile }
    }

    /// A [`ScenarioBuilder`] seeded with these options' prefix and
    /// simulated image count.
    pub fn builder(&self) -> ScenarioBuilder {
        ScenarioBuilder::from_prefix(&self.opts.prefix_spec()).sim_images(self.opts.sim_images)
    }

    /// The pipeline [`Scenario`] for one strategy name × design size
    /// under these options (the strategy's default dataflow).
    pub fn scenario(&self, alloc: &str, pes: usize) -> Result<Scenario> {
        self.builder().alloc(alloc).pes(pes).build()
    }

    /// Allocate + place + simulate one registry strategy on a chip of
    /// `pes` PEs.
    pub fn run_strategy(&self, alloc: &str, pes: usize) -> Result<(AllocationPlan, SimResult)> {
        let out = pipeline::run_scenario(&self.view(), &self.scenario(alloc, pes)?, None)?;
        Ok((out.plan, out.result))
    }

    /// Run all four paper algorithms at one design size; results are
    /// keyed by strategy name, in the Figs 8/9 series order.
    pub fn run_all(&self, pes: usize) -> Result<Vec<(String, SimResult)>> {
        PAPER_ALGORITHMS
            .into_iter()
            .map(|name| Ok((name.to_string(), self.run_strategy(name, pes)?.1)))
            .collect()
    }

    /// Minimum PEs that fit one copy of the network (paper: 86 for
    /// ResNet18 at the `rram-128` profile).
    pub fn min_pes(&self) -> usize {
        pipeline::min_pes_of(&self.map, self.hw.chip.arrays_per_pe)
    }

    /// The paper's design-size sweep: half-powers of two from the
    /// minimum (§V: "we begin increasing the design size by ½ powers
    /// of 2").
    pub fn sweep_sizes(&self, steps: usize) -> Vec<usize> {
        pipeline::sweep_sizes(self.min_pes(), steps)
    }

    /// All paper algorithms × sweep sizes as pipeline scenarios, ordered
    /// size-major (the Fig 8 table order).
    pub fn sweep_scenarios(&self, steps: usize) -> Vec<Scenario> {
        pipeline::scenarios_for(
            &self.opts.prefix_spec(),
            &self.sweep_sizes(steps),
            &StrategyRegistry::paper_allocators(),
            self.opts.sim_images,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipCfg;

    fn synth_driver(net: &str) -> Driver {
        Driver::prepare(DriverOpts {
            net: net.into(),
            hw: 32,
            profile_images: 1,
            sim_images: 4,
            ..DriverOpts::default()
        })
        .unwrap()
    }

    #[test]
    fn resnet18_min_pes_matches_paper() {
        let d = synth_driver("resnet18");
        assert_eq!(d.min_pes(), 86); // §V
    }

    #[test]
    fn sweep_sizes_half_powers() {
        let d = synth_driver("resnet18");
        let sizes = d.sweep_sizes(5);
        assert_eq!(sizes[0], 86);
        assert_eq!(sizes[2], 172);
        assert_eq!(sizes[4], 344);
        assert!((sizes[1] as f64 - 86.0 * 2f64.sqrt()).abs() < 1.0);
    }

    #[test]
    fn run_all_produces_ordered_speedups() {
        let d = synth_driver("resnet18");
        let results = d.run_all(172).unwrap();
        let get = |name: &str| {
            results.iter().find(|(a, _)| a == name).unwrap().1.throughput_ips
        };
        assert!(get("block-wise") >= get("perf-based"));
        assert!(get("perf-based") >= get("weight-based") * 0.95);
        assert!(get("weight-based") > get("baseline"));
    }

    #[test]
    fn vgg11_driver_works() {
        let d = synth_driver("vgg11");
        let (plan, result) = d.run_strategy("block-wise", d.min_pes() * 2).unwrap();
        plan.validate(&d.map, ChipCfg::paper(d.min_pes() * 2).total_arrays()).unwrap();
        assert!(result.throughput_ips > 0.0);
    }

    #[test]
    fn hybrid_runs_through_the_driver() {
        let d = synth_driver("resnet18");
        let (plan, result) = d.run_strategy("hybrid", d.min_pes() * 2).unwrap();
        assert_eq!(plan.algorithm, "hybrid");
        assert!(result.throughput_ips > 0.0);
    }

    #[test]
    fn unknown_net_rejected() {
        assert!(Driver::prepare(DriverOpts { net: "alexnet".into(), ..DriverOpts::default() })
            .is_err());
    }

    #[test]
    fn unknown_strategy_rejected_with_suggestion() {
        let d = synth_driver("resnet18");
        let err = d.run_strategy("blok-wise", 172).unwrap_err().to_string();
        assert!(err.contains("did you mean 'block-wise'?"), "{err}");
    }

    #[test]
    fn driver_run_matches_pipeline_scenario() {
        let d = synth_driver("resnet18");
        let (_, via_driver) = d.run_strategy("perf-based", 172).unwrap();
        let prep = pipeline::prepare(&d.opts.prefix_spec(), None).unwrap();
        let out = pipeline::run_scenario(
            &prep.view(),
            &d.scenario("perf-based", 172).unwrap(),
            None,
        )
        .unwrap();
        assert_eq!(via_driver.makespan, out.result.makespan);
        assert_eq!(via_driver.layer_util, out.result.layer_util);
    }

    #[test]
    fn hardware_profile_threads_through_the_driver() {
        let d = Driver::prepare(DriverOpts {
            net: "resnet18".into(),
            hw: 32,
            hw_profile: "sram-128".into(),
            profile_images: 1,
            sim_images: 4,
            ..DriverOpts::default()
        })
        .unwrap();
        assert_eq!(d.hw.name, "sram-128");
        assert_eq!(d.map.array.adc_bits, 6, "SRAM reads 64 rows per sample");
        let (_, r) = d.run_strategy("block-wise", d.min_pes() * 2).unwrap();
        assert!(r.throughput_ips > 0.0);
    }

    #[test]
    fn sweep_scenarios_cover_sizes_times_algorithms() {
        let d = synth_driver("resnet18");
        let scs = d.sweep_scenarios(3);
        assert_eq!(scs.len(), 12);
        assert!(scs.iter().all(|sc| sc.prefix == d.opts.prefix_spec()));
        assert_eq!(scs[0].pes, 86);
        assert_eq!(scs[4].pes, d.sweep_sizes(3)[1]);
    }
}
