//! End-to-end driver: a thin convenience wrapper over the staged
//! experiment pipeline ([`crate::pipeline`]).
//!
//! `Driver::prepare` runs the pipeline's shared prefix stages
//! (`BuildGraph → Map → Stats → Trace → Profile`) for one [`DriverOpts`];
//! `Driver::run` executes the scenario stages (`Allocate → Place →
//! Simulate`) for one algorithm × design size. Sweeps over many
//! scenarios should use [`crate::pipeline::run_sweep`] directly — it
//! shares the prepared prefix across scenarios and runs them on a
//! worker pool.

use crate::alloc::Algorithm;
use crate::mapping::AllocationPlan;
use crate::pipeline::{self, PrefixSpec, PreparedView, Scenario};
use crate::sim::SimResult;
use anyhow::Result;

pub use crate::pipeline::StatsSource;

/// Driver configuration.
#[derive(Debug, Clone)]
pub struct DriverOpts {
    pub net: String,
    /// Input resolution (must match the artifact when `Golden`).
    pub hw: usize,
    pub stats: StatsSource,
    /// Images used for profiling statistics.
    pub profile_images: usize,
    /// Images pushed through the pipelined simulation.
    pub sim_images: usize,
    pub seed: u64,
    pub artifacts_dir: String,
}

impl Default for DriverOpts {
    fn default() -> Self {
        DriverOpts {
            net: "resnet18".into(),
            hw: 64,
            stats: StatsSource::Synthetic,
            profile_images: 2,
            sim_images: 8,
            seed: 7,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl DriverOpts {
    /// The pipeline prefix these options describe.
    pub fn prefix_spec(&self) -> PrefixSpec {
        PrefixSpec {
            net: self.net.clone(),
            hw: self.hw,
            stats: self.stats,
            profile_images: self.profile_images,
            seed: self.seed,
            artifacts_dir: self.artifacts_dir.clone(),
        }
    }
}

/// A fully prepared experiment: everything up to (but excluding) the
/// allocation/simulation choices.
pub struct Driver {
    pub opts: DriverOpts,
    pub graph: crate::dnn::Graph,
    pub map: crate::mapping::NetworkMap,
    pub trace: crate::stats::NetTrace,
    pub profile: crate::stats::NetworkProfile,
}

impl Driver {
    /// Run the pipeline prefix stages: build the graph, gather
    /// statistics, derive the profile.
    pub fn prepare(opts: DriverOpts) -> Result<Driver> {
        let prep = pipeline::prepare(&opts.prefix_spec(), None)?;
        Ok(Driver {
            opts,
            graph: prep.graph,
            map: prep.map,
            trace: prep.trace,
            profile: prep.profile,
        })
    }

    fn view(&self) -> PreparedView<'_> {
        PreparedView { map: &self.map, trace: &self.trace, profile: &self.profile }
    }

    /// The pipeline [`Scenario`] for one algorithm × design size under
    /// these options.
    pub fn scenario(&self, alg: Algorithm, pes: usize) -> Scenario {
        Scenario {
            prefix: self.opts.prefix_spec(),
            alg,
            pes,
            sim_images: self.opts.sim_images,
        }
    }

    /// Allocate + place + simulate one algorithm on a chip of `pes` PEs.
    pub fn run(&self, alg: Algorithm, pes: usize) -> Result<(AllocationPlan, SimResult)> {
        let out = pipeline::run_scenario(&self.view(), &self.scenario(alg, pes), None)?;
        Ok((out.plan, out.result))
    }

    /// Run all four paper algorithms at one design size.
    pub fn run_all(&self, pes: usize) -> Result<Vec<(Algorithm, SimResult)>> {
        Algorithm::all()
            .into_iter()
            .map(|alg| Ok((alg, self.run(alg, pes)?.1)))
            .collect()
    }

    /// Minimum PEs that fit one copy of the network (paper: 86 for
    /// ResNet18).
    pub fn min_pes(&self) -> usize {
        pipeline::min_pes_of(&self.map)
    }

    /// The paper's design-size sweep: half-powers of two from the
    /// minimum (§V: "we begin increasing the design size by ½ powers
    /// of 2").
    pub fn sweep_sizes(&self, steps: usize) -> Vec<usize> {
        pipeline::sweep_sizes(self.min_pes(), steps)
    }

    /// All paper algorithms × sweep sizes as pipeline scenarios, ordered
    /// size-major (the Fig 8 table order).
    pub fn sweep_scenarios(&self, steps: usize) -> Vec<Scenario> {
        pipeline::scenarios_for(
            &self.opts.prefix_spec(),
            &self.sweep_sizes(steps),
            &Algorithm::all(),
            self.opts.sim_images,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipCfg;

    fn synth_driver(net: &str) -> Driver {
        Driver::prepare(DriverOpts {
            net: net.into(),
            hw: 32,
            profile_images: 1,
            sim_images: 4,
            ..DriverOpts::default()
        })
        .unwrap()
    }

    #[test]
    fn resnet18_min_pes_matches_paper() {
        let d = synth_driver("resnet18");
        assert_eq!(d.min_pes(), 86); // §V
    }

    #[test]
    fn sweep_sizes_half_powers() {
        let d = synth_driver("resnet18");
        let sizes = d.sweep_sizes(5);
        assert_eq!(sizes[0], 86);
        assert_eq!(sizes[2], 172);
        assert_eq!(sizes[4], 344);
        assert!((sizes[1] as f64 - 86.0 * 2f64.sqrt()).abs() < 1.0);
    }

    #[test]
    fn run_all_produces_ordered_speedups() {
        let d = synth_driver("resnet18");
        let results = d.run_all(172).unwrap();
        let get = |alg: Algorithm| {
            results.iter().find(|(a, _)| *a == alg).unwrap().1.throughput_ips
        };
        assert!(get(Algorithm::BlockWise) >= get(Algorithm::PerfBased));
        assert!(get(Algorithm::PerfBased) >= get(Algorithm::WeightBased) * 0.95);
        assert!(get(Algorithm::WeightBased) > get(Algorithm::Baseline));
    }

    #[test]
    fn vgg11_driver_works() {
        let d = synth_driver("vgg11");
        let (plan, result) = d.run(Algorithm::BlockWise, d.min_pes() * 2).unwrap();
        plan.validate(&d.map, ChipCfg::paper(d.min_pes() * 2).total_arrays()).unwrap();
        assert!(result.throughput_ips > 0.0);
    }

    #[test]
    fn unknown_net_rejected() {
        assert!(Driver::prepare(DriverOpts { net: "alexnet".into(), ..DriverOpts::default() })
            .is_err());
    }

    #[test]
    fn driver_run_matches_pipeline_scenario() {
        let d = synth_driver("resnet18");
        let (_, via_driver) = d.run(Algorithm::PerfBased, 172).unwrap();
        let prep = pipeline::prepare(&d.opts.prefix_spec(), None).unwrap();
        let out = pipeline::run_scenario(
            &prep.view(),
            &d.scenario(Algorithm::PerfBased, 172),
            None,
        )
        .unwrap();
        assert_eq!(via_driver.makespan, out.result.makespan);
        assert_eq!(via_driver.layer_util, out.result.layer_util);
    }

    #[test]
    fn sweep_scenarios_cover_sizes_times_algorithms() {
        let d = synth_driver("resnet18");
        let scs = d.sweep_scenarios(3);
        assert_eq!(scs.len(), 12);
        assert!(scs.iter().all(|sc| sc.prefix == d.opts.prefix_spec()));
        assert_eq!(scs[0].pes, 86);
        assert_eq!(scs[4].pes, d.sweep_sizes(3)[1]);
    }
}
