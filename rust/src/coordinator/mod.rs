//! L3 coordinator: the end-to-end driver and the live block-wise
//! dispatch engine.
//!
//! * [`driver`] wires the whole stack together: artifacts → activation
//!   profiling (PJRT golden or synthetic) → mapping → allocation →
//!   cycle-accurate simulation → report tables. This is what the CLI and
//!   the examples call.
//! * [`dispatch`] is a *live* implementation of the paper's block-wise
//!   dataflow (§III-C): a memory-controller work queue, one worker
//!   thread per physical block instance computing real partial dot
//!   products on programmed [`crate::xbar::SubArray`]s, and a
//!   vector-unit thread that gathers packetized partial sums by
//!   destination-accumulator id. Output feature maps are verified
//!   against the reference convolution — the dataflow is not just
//!   simulated, it runs.

pub mod driver;
pub mod dispatch;

pub use driver::{Driver, DriverOpts, StatsSource};
