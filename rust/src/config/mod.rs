//! Chip / PE / array configuration — the *lowered* operating point.
//!
//! Mirrors the paper's simulator inputs (§V): "the PE-level configuration
//! includes details like the precision of each ADC and size of the
//! sub-array. The chip-level configuration contains the number of PEs and
//! details about array allocation and mapping." Configurations load/save
//! as JSON via [`crate::util::json`].
//!
//! Since the hardware description API landed, these flat structs are the
//! *derived* form a [`crate::hw::HwProfile`] lowers into: `adc_bits`
//! comes from the device's variance budget, `cell_bits` from the device
//! model. Construct them through a profile
//! ([`crate::hw::HwProfile::array_cfg`] / [`chip_cfg`][hwc]) rather than
//! by hand; [`ArrayCfg::paper`] / [`ChipCfg::paper`] survive as
//! deprecated shims resolving the `rram-128` profile.
//!
//! [hwc]: crate::hw::HwProfile::chip_cfg

use crate::util::json::Json;

/// Sub-array geometry + read discipline (paper §II, §IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrayCfg {
    /// Word lines per array (paper: 128).
    pub rows: usize,
    /// Bit lines per array (paper: 128).
    pub cols: usize,
    /// Bits per weight → binary cells per weight column (paper: 8).
    pub weight_bits: usize,
    /// Bits per input, shifted in serially (paper: 8).
    pub input_bits: usize,
    /// ADC precision in bits; 2^adc_bits rows are read per ADC sample
    /// (paper: 3 → 8 rows, the max readable without error at 5%
    /// device variance [4]).
    pub adc_bits: usize,
    /// Columns sharing one ADC through a mux (paper: 8 → 16 ADCs/array).
    pub col_mux: usize,
    /// Zero-skipping: a bit-plane with no '1's costs zero cycles.
    /// (true for all paper configurations; baseline-vs-zs is a run mode,
    /// not an array property — see [`crate::xbar::ReadMode`]).
    pub skip_empty_planes: bool,
    /// Bits stored per eNVM cell (paper: 1 — "we focus our attention to
    /// binary cells given the current state of the art [4] already
    /// struggles with variance"; §II notes the techniques extend to
    /// multi-level cells, which this models: an 8-bit weight spans
    /// `weight_bits / cell_bits` columns).
    pub cell_bits: usize,
}

impl ArrayCfg {
    /// The paper's operating point.
    ///
    /// **Deprecated shim** — resolves the `rram-128` profile through
    /// [`crate::hw::ProfileRegistry`] and lowers it (bit-identical to
    /// the historical literal constants, pinned by the `hw_profiles`
    /// parity test). New code should name a profile instead.
    pub fn paper() -> ArrayCfg {
        crate::hw::ProfileRegistry::lookup(crate::hw::DEFAULT_PROFILE)
            .and_then(|p| p.array_cfg())
            .expect("the built-in rram-128 profile is always valid")
    }

    /// Rows read per ADC sample.
    pub fn adc_rows(&self) -> usize {
        1 << self.adc_bits
    }

    /// Checked constructive constraints — what the old `assert!`s
    /// enforced, as errors. Called on every JSON load and by
    /// [`crate::hw::ArraySpec::lower`], so invalid geometry surfaces
    /// through [`crate::pipeline::ScenarioBuilder`] instead of
    /// panicking mid-run.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.rows >= 1 && self.cols >= 1,
            "array geometry must be nonzero, got {}x{}",
            self.rows,
            self.cols
        );
        anyhow::ensure!(
            (1..=8).contains(&self.input_bits),
            "input_bits must be in 1..=8 (bit-serial u8 datapath), got {}",
            self.input_bits
        );
        anyhow::ensure!(
            (1..=10).contains(&self.adc_bits),
            "adc_bits must be in 1..=10, got {}",
            self.adc_bits
        );
        anyhow::ensure!(
            self.cell_bits >= 1 && self.weight_bits >= 1,
            "weight and cell widths must be nonzero"
        );
        anyhow::ensure!(
            self.weight_bits % self.cell_bits == 0,
            "weight_bits {} not divisible by cell_bits {}",
            self.weight_bits,
            self.cell_bits
        );
        anyhow::ensure!(
            self.cols % (self.weight_bits / self.cell_bits) == 0,
            "cols {} not divisible by the {} cells per weight",
            self.cols,
            self.weight_bits / self.cell_bits
        );
        anyhow::ensure!(
            self.col_mux >= 1 && self.cols % self.col_mux == 0,
            "cols {} not divisible by col_mux {}",
            self.cols,
            self.col_mux
        );
        Ok(())
    }

    /// Physical cells (columns) per stored weight.
    ///
    /// Divisibility is a [`ArrayCfg::validate`] invariant: every
    /// supported construction path (profile lowering, JSON loads, the
    /// scenario builder) surfaces the violation as a `Result` long
    /// before this is called. The assert remains only as a loud
    /// backstop for hand-built configs that bypassed validation.
    pub fn cells_per_weight(&self) -> usize {
        assert!(
            self.weight_bits % self.cell_bits == 0,
            "weight_bits {} not divisible by cell_bits {} — validate() was skipped",
            self.weight_bits,
            self.cell_bits
        );
        self.weight_bits / self.cell_bits
    }

    /// Weight columns per array (paper: 16 with binary cells).
    pub fn weight_cols(&self) -> usize {
        self.cols / self.cells_per_weight()
    }

    /// ADCs per array (paper: 16).
    pub fn adcs(&self) -> usize {
        self.cols / self.col_mux
    }

    /// Worst-case cycles for a full-array dot product (paper: 1024).
    pub fn worst_case_cycles(&self) -> u64 {
        (self.input_bits * self.rows.div_ceil(self.adc_rows()) * self.col_mux) as u64
    }

    /// Best-case cycles (paper: 64).
    pub fn best_case_cycles(&self) -> u64 {
        (self.input_bits * self.col_mux) as u64
    }

    /// Deterministic JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("weight_bits", Json::num(self.weight_bits as f64)),
            ("input_bits", Json::num(self.input_bits as f64)),
            ("adc_bits", Json::num(self.adc_bits as f64)),
            ("col_mux", Json::num(self.col_mux as f64)),
            ("skip_empty_planes", Json::Bool(self.skip_empty_planes)),
            ("cell_bits", Json::num(self.cell_bits as f64)),
        ])
    }

    /// Parse from JSON, filling absent fields with paper defaults;
    /// validates the result.
    pub fn from_json(j: &Json) -> crate::Result<ArrayCfg> {
        let d = ArrayCfg::paper();
        let cfg = ArrayCfg {
            rows: j.get("rows").as_usize().unwrap_or(d.rows),
            cols: j.get("cols").as_usize().unwrap_or(d.cols),
            weight_bits: j.get("weight_bits").as_usize().unwrap_or(d.weight_bits),
            input_bits: j.get("input_bits").as_usize().unwrap_or(d.input_bits),
            adc_bits: j.get("adc_bits").as_usize().unwrap_or(d.adc_bits),
            col_mux: j.get("col_mux").as_usize().unwrap_or(d.col_mux),
            skip_empty_planes: j.get("skip_empty_planes").as_bool().unwrap_or(true),
            cell_bits: j.get("cell_bits").as_usize().unwrap_or(d.cell_bits),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Chip-level configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ChipCfg {
    /// Processing elements on chip; each holds `arrays_per_pe` arrays.
    pub pes: usize,
    /// Arrays per PE (paper: 64).
    pub arrays_per_pe: usize,
    /// Clock (paper: 100 MHz).
    pub clock_hz: f64,
    /// Sub-array geometry + read discipline.
    pub array: ArrayCfg,
    /// Feature/psum packet sizes in bytes (for the NoC model).
    pub feature_packet_bytes: usize,
    /// Partial-sum packet size in bytes.
    pub psum_packet_bytes: usize,
    /// NoC link payload bytes moved per cycle per link.
    pub link_bytes_per_cycle: usize,
    /// Per-hop router latency in cycles.
    pub router_latency: usize,
    /// Images in flight for pipelined simulation.
    pub pipeline_images: usize,
}

impl ChipCfg {
    /// Paper defaults at a given PE count (paper sweeps 86.. for ResNet18).
    ///
    /// **Deprecated shim** — resolves the `rram-128` profile through
    /// [`crate::hw::ProfileRegistry`] and lowers it at `pes` PEs. New
    /// code should name a profile ([`crate::hw::HwProfile::chip_cfg`]).
    pub fn paper(pes: usize) -> ChipCfg {
        crate::hw::ProfileRegistry::lookup(crate::hw::DEFAULT_PROFILE)
            .and_then(|p| p.chip_cfg(pes))
            .expect("the built-in rram-128 profile is always valid (pes >= 1)")
    }

    /// Total arrays on chip.
    pub fn total_arrays(&self) -> usize {
        self.pes * self.arrays_per_pe
    }

    /// Mesh side length (paper: N×N mesh, Fig 7).
    pub fn mesh_side(&self) -> usize {
        (self.pes as f64).sqrt().ceil() as usize
    }

    /// Deterministic JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pes", Json::num(self.pes as f64)),
            ("arrays_per_pe", Json::num(self.arrays_per_pe as f64)),
            ("clock_hz", Json::num(self.clock_hz)),
            ("array", self.array.to_json()),
            ("feature_packet_bytes", Json::num(self.feature_packet_bytes as f64)),
            ("psum_packet_bytes", Json::num(self.psum_packet_bytes as f64)),
            ("link_bytes_per_cycle", Json::num(self.link_bytes_per_cycle as f64)),
            ("router_latency", Json::num(self.router_latency as f64)),
            ("pipeline_images", Json::num(self.pipeline_images as f64)),
        ])
    }

    /// Parse from JSON; `pes` is required, everything else defaults to
    /// the paper point at that size.
    pub fn from_json(j: &Json) -> crate::Result<ChipCfg> {
        let pes = j
            .get("pes")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("chip config needs integer 'pes'"))?;
        let d = ChipCfg::paper(pes);
        Ok(ChipCfg {
            pes,
            arrays_per_pe: j.get("arrays_per_pe").as_usize().unwrap_or(d.arrays_per_pe),
            clock_hz: j.get("clock_hz").as_f64().unwrap_or(d.clock_hz),
            array: ArrayCfg::from_json(j.get("array"))?,
            feature_packet_bytes: j
                .get("feature_packet_bytes")
                .as_usize()
                .unwrap_or(d.feature_packet_bytes),
            psum_packet_bytes: j.get("psum_packet_bytes").as_usize().unwrap_or(d.psum_packet_bytes),
            link_bytes_per_cycle: j
                .get("link_bytes_per_cycle")
                .as_usize()
                .unwrap_or(d.link_bytes_per_cycle),
            router_latency: j.get("router_latency").as_usize().unwrap_or(d.router_latency),
            pipeline_images: j.get("pipeline_images").as_usize().unwrap_or(d.pipeline_images),
        })
    }

    /// Load a chip-config JSON from `path`.
    pub fn load(path: &str) -> crate::Result<ChipCfg> {
        let text = std::fs::read_to_string(path)?;
        ChipCfg::from_json(&Json::parse(&text)?)
    }

    /// Write the config JSON to `path`.
    pub fn save(&self, path: &str) -> crate::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_operating_point() {
        let a = ArrayCfg::paper();
        assert_eq!(a.adc_rows(), 8);
        assert_eq!(a.weight_cols(), 16);
        assert_eq!(a.adcs(), 16);
        // §IV: "each array takes anywhere from 64 to 1024 cycles"
        assert_eq!(a.best_case_cycles(), 64);
        assert_eq!(a.worst_case_cycles(), 1024);
    }

    #[test]
    fn chip_defaults() {
        let c = ChipCfg::paper(86);
        assert_eq!(c.total_arrays(), 5504); // ≥ 5472 min for ResNet18
        assert_eq!(c.mesh_side(), 10);
    }

    #[test]
    fn json_roundtrip() {
        let c = ChipCfg::paper(123);
        let j = c.to_json();
        let c2 = ChipCfg::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn from_json_fills_defaults() {
        let j = Json::parse(r#"{"pes": 10}"#).unwrap();
        let c = ChipCfg::from_json(&j).unwrap();
        assert_eq!(c.pes, 10);
        assert_eq!(c.arrays_per_pe, 64);
        assert_eq!(c.array.adc_bits, 3);
    }

    #[test]
    fn missing_pes_is_error() {
        let j = Json::parse("{}").unwrap();
        assert!(ChipCfg::from_json(&j).is_err());
    }

    #[test]
    fn invalid_geometry_is_an_error_not_a_panic() {
        let mut a = ArrayCfg::paper();
        assert!(a.validate().is_ok());
        a.cell_bits = 3; // 8 % 3 != 0
        assert!(a.validate().is_err());
        assert!(ArrayCfg::from_json(&a.to_json()).is_err());
        let mut a = ArrayCfg::paper();
        a.col_mux = 7;
        assert!(a.validate().is_err());
        let mut a = ArrayCfg::paper();
        a.rows = 0;
        assert!(a.validate().is_err());
    }

    #[test]
    fn paper_shims_match_the_rram_128_profile() {
        let p = crate::hw::ProfileRegistry::lookup("rram-128").unwrap();
        assert_eq!(ArrayCfg::paper(), p.array_cfg().unwrap());
        assert_eq!(ChipCfg::paper(86), p.chip_cfg(86).unwrap());
    }
}
