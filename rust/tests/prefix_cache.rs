//! Content-addressed prefix cache: miss → store → hit reconstruction
//! must be bit-identical to a cold run — same trace, same stage
//! artifacts, same downstream simulation — and every corruption or
//! mismatch must degrade to a miss, never a wrong answer.

use cimfab::pipeline::{
    self, artifact, prepare_cached, run_sweep, CacheStatus, Dumper, PrefixCache, PrefixSpec,
    ScenarioBuilder, StatsSource, SweepCfg,
};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cimfab_prefix_cache_{name}_{}", std::process::id()))
}

fn spec(seed: u64) -> PrefixSpec {
    PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed,
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn miss_then_hit_reconstructs_an_identical_prefix() {
    let dir = tmp("hit");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    let (cold, s0) = prepare_cached(&spec(7), None, Some(&cache)).unwrap();
    assert_eq!(s0, CacheStatus::Miss);
    let (warm, s1) = prepare_cached(&spec(7), None, Some(&cache)).unwrap();
    assert_eq!(s1, CacheStatus::Hit);
    assert_eq!(cold.trace, warm.trace);
    assert_eq!(cold.min_pes(), warm.min_pes());
    assert_eq!(
        artifact::trace_json(&cold.map, &cold.trace).compact(),
        artifact::trace_json(&warm.map, &warm.trace).compact()
    );
    assert_eq!(
        artifact::profile_json(&cold.profile).compact(),
        artifact::profile_json(&warm.profile).compact()
    );
    // the warm prefix drives the scenario stages to the same result
    let sc = ScenarioBuilder::from_prefix(&spec(7))
        .alloc("block-wise")
        .pes(172)
        .sim_images(2)
        .build()
        .unwrap();
    let a = pipeline::run_scenario(&cold.view(), &sc, None).unwrap();
    let b = pipeline::run_scenario(&warm.view(), &sc, None).unwrap();
    assert_eq!(
        artifact::sim_result_json(&a.result).compact(),
        artifact::sim_result_json(&b.result).compact()
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_dump_trees_are_byte_identical_to_cold_ones() {
    let (cache_dir, da, db) = (tmp("dump_cache"), tmp("dump_a"), tmp("dump_b"));
    for d in [&cache_dir, &da, &db] {
        let _ = std::fs::remove_dir_all(d);
    }
    let cache = PrefixCache::new(cache_dir.to_str().unwrap()).unwrap();
    let s = spec(9);
    let (_, s0) = prepare_cached(&s, Some(&Dumper::new(da.to_str().unwrap()).unwrap()),
        Some(&cache)).unwrap();
    assert_eq!(s0, CacheStatus::Miss);
    let (_, s1) = prepare_cached(&s, Some(&Dumper::new(db.to_str().unwrap()).unwrap()),
        Some(&cache)).unwrap();
    assert_eq!(s1, CacheStatus::Hit);
    let sub = s.id();
    let mut checked = 0;
    for entry in std::fs::read_dir(da.join(&sub)).unwrap() {
        let name = entry.unwrap().file_name();
        let a = std::fs::read(da.join(&sub).join(&name)).unwrap();
        let b = std::fs::read(db.join(&sub).join(&name)).unwrap();
        assert_eq!(a, b, "stage dump {name:?} differs between cold and warm runs");
        checked += 1;
    }
    assert_eq!(checked, 5, "expected the five prefix stage artifacts");
    for d in [&cache_dir, &da, &db] {
        std::fs::remove_dir_all(d).unwrap();
    }
}

#[test]
fn corrupt_entries_degrade_to_a_miss_and_are_repaired() {
    let dir = tmp("corrupt");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    let (cold, s0) = prepare_cached(&spec(11), None, Some(&cache)).unwrap();
    assert_eq!(s0, CacheStatus::Miss);
    let entry = std::fs::read_dir(&dir).unwrap().next().unwrap().unwrap().path();
    std::fs::write(&entry, "{not json").unwrap();
    let (again, s1) = prepare_cached(&spec(11), None, Some(&cache)).unwrap();
    assert_eq!(s1, CacheStatus::Miss, "corrupt entry must not be replayed");
    assert_eq!(cold.trace, again.trace);
    let (_, s2) = prepare_cached(&spec(11), None, Some(&cache)).unwrap();
    assert_eq!(s2, CacheStatus::Hit, "the repaired entry hits again");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn killed_mid_write_entries_miss_cleanly_and_are_repaired() {
    let dir = tmp("killed");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    let (cold, s0) = prepare_cached(&spec(17), None, Some(&cache)).unwrap();
    assert_eq!(s0, CacheStatus::Miss);
    let entry = std::fs::read_dir(&dir).unwrap().next().unwrap().unwrap().path();
    let full = std::fs::read(&entry).unwrap();
    // a writer killed before its atomic rename leaves only a partial
    // .tmp file behind; the cache must ignore it entirely
    let stray = entry.with_extension("tmp.99999.0");
    std::fs::write(&stray, &full[..full.len() / 3]).unwrap();
    // and a torn entry (however it got there) must degrade to a clean
    // miss at any truncation point — never a panic or a wrong replay
    for cut in [0, 1, full.len() / 2, full.len() - 1] {
        std::fs::write(&entry, &full[..cut]).unwrap();
        let (again, status) = prepare_cached(&spec(17), None, Some(&cache)).unwrap();
        assert_eq!(status, CacheStatus::Miss, "entry truncated at {cut} bytes must miss");
        assert_eq!(cold.trace, again.trace, "repair after truncation at {cut} diverged");
    }
    // the last repair rewrote a whole entry: it hits, byte-identical to
    // the original, and the only tmp file around is the dead writer's
    assert_eq!(prepare_cached(&spec(17), None, Some(&cache)).unwrap().1, CacheStatus::Hit);
    assert_eq!(std::fs::read(&entry).unwrap(), full, "repaired entry must be byte-identical");
    let tmp_files: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.contains(".tmp."))
        .collect();
    assert_eq!(
        tmp_files,
        vec![stray.file_name().unwrap().to_string_lossy().into_owned()],
        "repair must not leave tmp files of its own"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn golden_prefixes_never_write_cache_entries() {
    let dir = tmp("golden");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    let mut s = spec(7);
    s.stats = StatsSource::Golden;
    s.artifacts_dir = dir.join("no_such_artifacts").to_str().unwrap().to_string();
    // golden statistics read artifact files the key cannot see, so the
    // cache must stay out of the way entirely (here: the failure to load
    // the artifacts surfaces, and no entry is written)
    assert!(prepare_cached(&s, None, Some(&cache)).is_err());
    assert!(
        std::fs::read_dir(&dir).unwrap().next().is_none(),
        "golden prefix must not create cache entries"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn synthetic_specs_differing_only_in_artifacts_dir_share_an_entry() {
    // artifacts_dir is unused under synthetic statistics (PrefixSpec::id
    // ignores it, pinned by the determinism suite), so it must not
    // defeat the cache either
    let dir = tmp("artdir");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    let (_, s0) = prepare_cached(&spec(13), None, Some(&cache)).unwrap();
    assert_eq!(s0, CacheStatus::Miss);
    let mut other = spec(13);
    other.artifacts_dir = "elsewhere".into();
    let (warm, s1) = prepare_cached(&other, None, Some(&cache)).unwrap();
    assert_eq!(s1, CacheStatus::Hit, "unused artifacts_dir must not force a miss");
    // the reconstructed prefix carries the requesting spec verbatim
    assert_eq!(warm.spec.artifacts_dir, "elsewhere");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn distinct_specs_get_distinct_entries() {
    let dir = tmp("distinct");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    prepare_cached(&spec(1), None, Some(&cache)).unwrap();
    prepare_cached(&spec(2), None, Some(&cache)).unwrap();
    let entries = std::fs::read_dir(&dir).unwrap().count();
    assert_eq!(entries, 2, "different seeds must be keyed apart");
    // both hit afterwards
    assert_eq!(prepare_cached(&spec(1), None, Some(&cache)).unwrap().1, CacheStatus::Hit);
    assert_eq!(prepare_cached(&spec(2), None, Some(&cache)).unwrap().1, CacheStatus::Hit);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn cached_sweeps_reproduce_uncached_results() {
    let dir = tmp("sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let scenarios: Vec<_> = ["baseline", "block-wise"]
        .into_iter()
        .map(|alloc| {
            ScenarioBuilder::from_prefix(&spec(5))
                .alloc(alloc)
                .pes(129)
                .sim_images(2)
                .build()
                .unwrap()
        })
        .collect();
    let cached_cfg = SweepCfg {
        threads: 2,
        dump_dir: None,
        cache_dir: Some(dir.to_str().unwrap().to_string()),
    };
    let cold = run_sweep(&scenarios, &cached_cfg).unwrap();
    assert!(std::fs::read_dir(&dir).unwrap().next().is_some(), "sweep must populate the cache");
    let warm = run_sweep(&scenarios, &cached_cfg).unwrap();
    let plain = run_sweep(&scenarios, &SweepCfg::serial()).unwrap();
    for ((c, w), p) in cold.iter().zip(&warm).zip(&plain) {
        let json = |o: &pipeline::ScenarioOutcome| artifact::sim_result_json(&o.result).compact();
        assert_eq!(json(c), json(w), "warm sweep diverged at {}", c.scenario.id());
        assert_eq!(json(c), json(p), "cached sweep diverged from uncached at {}", c.scenario.id());
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
