//! Weight-pool subsystem guarantees, end to end:
//!
//! * **resolve precedence** — `--hw`'s name-or-path grammar: a local
//!   file can never shadow a registered profile name, but unknown bare
//!   names still fall back to a file of that name;
//! * **unit-ratio identity** — propcheck: `pooled` at oversub 1.0 is
//!   byte-identical (via the plan artifact) to the block-wise allocator
//!   across random budgets, so turning the axis off costs nothing;
//! * **big-nets-on-small-chips** — ResNet18 completes on a quarter-size
//!   rram-128 chip at 4x oversubscription with at least one reload,
//!   itemized reload cells/stalls, and the schedule in the plan artifact;
//! * **refusal** — non-pooled strategies reject oversubscription through
//!   the pipeline with actionable guidance.

use cimfab::alloc::{greedy, Allocator};
use cimfab::config::ArrayCfg;
use cimfab::dnn::resnet18;
use cimfab::mapping::{map_network, NetworkMap};
use cimfab::pipeline::{self, artifact, PrefixSpec, ScenarioBuilder, StatsSource};
use cimfab::stats::synth::{synth_activations, SynthCfg};
use cimfab::stats::{trace_from_activations, NetworkProfile};
use cimfab::strategy::StrategyRegistry;
use cimfab::util::propcheck;

fn spec() -> PrefixSpec {
    PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed: 7,
        artifacts_dir: "artifacts".into(),
    }
}

fn setup() -> (NetworkMap, NetworkProfile) {
    let g = resnet18(32, 10);
    let map = map_network(&g, ArrayCfg::paper(), false);
    let acts = synth_activations(&g, &map, 2, 17, SynthCfg::default());
    let trace = trace_from_activations(&g, &map, &acts);
    let prof = NetworkProfile::from_trace(&map, &trace);
    (map, prof)
}

#[test]
fn resolve_precedence_registered_name_beats_local_file() {
    use cimfab::hw::{HwProfile, ProfileRegistry};
    let dir = std::env::temp_dir().join(format!("cimfab_wp_resolve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // a local file named exactly like a registered alias …
    let mut shadow = HwProfile::rram_256();
    shadow.name = "local-shadow".into();
    shadow.save(dir.join("pcram").to_str().unwrap()).unwrap();
    // … and one whose name the registry does not know
    let mut fallback = HwProfile::rram_256();
    fallback.name = "from-file".into();
    fallback.save(dir.join("localonly").to_str().unwrap()).unwrap();

    // run the bare-name lookups from inside the directory, then restore
    // the working directory before asserting
    let old = std::env::current_dir().unwrap();
    std::env::set_current_dir(&dir).unwrap();
    let named = ProfileRegistry::resolve("pcram");
    let file = ProfileRegistry::resolve("localonly");
    std::env::set_current_dir(old).unwrap();

    assert_eq!(
        named.unwrap().name,
        "pcram-128",
        "a local file must never shadow a registered name"
    );
    assert_eq!(
        file.unwrap().name,
        "from-file",
        "unknown bare names fall back to a local file"
    );
    // an explicit path always loads the file, registered name or not
    let by_path = ProfileRegistry::resolve(dir.join("pcram").to_str().unwrap()).unwrap();
    assert_eq!(by_path.name, "local-shadow");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pooled_at_unit_ratio_matches_blockwise_byte_for_byte() {
    let (map, prof) = setup();
    let pooled = StrategyRegistry::lookup_allocator("pooled").unwrap();
    propcheck::check("pooled@1.0 == block-wise", 0xB10C, 20, |rng| {
        let budget = map.min_arrays() + rng.index(map.min_arrays() * 2 + 1);
        let got = pooled.allocate(&map, &prof, budget).unwrap();
        // the pre-pool path, restamped the way the registry parity test
        // normalizes algorithm names
        let mut want = greedy::blockwise(&map, &prof.block_cycles, budget).unwrap();
        want.algorithm = "pooled".into();
        cimfab::prop_assert!(
            artifact::plan_json(&got, &map).pretty() == artifact::plan_json(&want, &map).pretty(),
            "budget {budget}: pooled@1.0 diverged from block-wise"
        );
        // the explicit-ratio entry point agrees at exactly 1.0
        let via_ratio = pooled.allocate_oversub(&map, &prof, budget, 1.0).unwrap();
        cimfab::prop_assert!(
            artifact::plan_json(&via_ratio, &map).pretty()
                == artifact::plan_json(&want, &map).pretty(),
            "budget {budget}: allocate_oversub(1.0) diverged"
        );
        Ok(())
    });
}

#[test]
fn resnet18_completes_on_a_quarter_size_chip() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let pes = prep.min_pes().div_ceil(4);
    let sc = ScenarioBuilder::from_prefix(&spec())
        .alloc("pooled")
        .pes(pes)
        .sim_images(2)
        .oversub(4.0)
        .build()
        .unwrap();
    assert!(sc.id().ends_with("_ov4"), "{}", sc.id());
    let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();

    // the oversubscribed run actually swapped pools and charged for it
    assert!(out.result.reloads >= 1, "quarter chip must reload at least once");
    assert!(out.result.reload_cells > 0);
    assert!(out.result.reload_stall_cycles > 0);
    assert!(out.result.throughput_ips > 0.0);

    // the reprogramming schedule rides the plan artifact …
    let pj = artifact::plan_json(&out.plan, &prep.map);
    let pools = pj.get("pools").get("pools").as_arr().unwrap();
    assert!(pools.len() > 1, "schedule should partition the net into several pools");
    // … and the reload counters ride the report
    let rep = out.report_json();
    assert!(rep.get("reloads").as_u64().unwrap() >= 1);
    assert!(rep.get("reload_cells").as_u64().unwrap() > 0);

    // a full-size run of the same scenario id family stays reload-free
    let full = ScenarioBuilder::from_prefix(&spec())
        .alloc("pooled")
        .pes(prep.min_pes())
        .sim_images(2)
        .build()
        .unwrap();
    let full_out = pipeline::run_scenario(&prep.view(), &full, None).unwrap();
    assert_eq!(full_out.result.reloads, 0);
    assert!(full_out.report_json().get("reloads").as_u64().is_none());
}

#[test]
fn non_pooled_strategies_reject_oversubscription_through_the_pipeline() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let sc = ScenarioBuilder::from_prefix(&spec())
        .alloc("block-wise")
        .pes(prep.min_pes())
        .sim_images(2)
        .oversub(2.0)
        .build()
        .unwrap();
    let err = format!("{:#}", pipeline::run_scenario(&prep.view(), &sc, None).unwrap_err());
    assert!(err.contains("cannot oversubscribe"), "{err}");
    assert!(err.contains("pooled"), "guidance should point at --alloc pooled: {err}");
}
