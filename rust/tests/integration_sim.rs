//! Integration: allocation × placement × simulation across modules.

use cimfab::alloc::Allocator;
use cimfab::config::{ArrayCfg, ChipCfg};
use cimfab::coordinator::{Driver, DriverOpts, StatsSource};
use cimfab::dnn::resnet18;
use cimfab::mapping::{map_network, place, AllocationPlan};
use cimfab::sim::dataflow::{BLOCK_WISE, LAYER_WISE};
use cimfab::sim::{simulate, SimCfg};
use cimfab::strategy::{StrategyRegistry, PAPER_ALGORITHMS};
use cimfab::stats::synth::{synth_activations, SynthCfg};
use cimfab::stats::{trace_from_activations, NetworkProfile};
use cimfab::xbar::ReadMode;

fn driver() -> Driver {
    Driver::prepare(DriverOpts {
        net: "resnet18".into(),
        hw: 32,
        stats: StatsSource::Synthetic,
        profile_images: 2,
        sim_images: 6,
        seed: 99,
        ..DriverOpts::default()
    })
    .unwrap()
}

#[test]
fn paper_ordering_holds_across_design_sizes() {
    let d = driver();
    for pes in [129, 172, 344] {
        let results = d.run_all(pes).unwrap();
        let get = |alloc: &str| {
            results.iter().find(|(a, _)| a == alloc).unwrap().1.throughput_ips
        };
        assert!(
            get("block-wise") >= get("perf-based") * 0.99,
            "pes={pes}: block-wise loses to perf-based"
        );
        assert!(
            get("perf-based") >= get("weight-based") * 0.9,
            "pes={pes}: perf-based loses to weight-based"
        );
        assert!(
            get("weight-based") > get("baseline"),
            "pes={pes}: zero-skipping loses to baseline"
        );
    }
}

#[test]
fn min_size_all_zs_algorithms_close() {
    // Paper §V: "At 86 PEs, all algorithms yield the same result since no
    // duplication can be done" (modulo the dataflow's barrier removal).
    let d = driver();
    let results = d.run_all(86).unwrap();
    let get = |alloc: &str| results.iter().find(|(a, _)| a == alloc).unwrap().1.throughput_ips;
    let wb = get("weight-based");
    let pb = get("perf-based");
    assert!((wb - pb).abs() / wb < 1e-9, "layer-wise ZS algorithms must coincide at min size");
    let bw = get("block-wise");
    assert!(bw >= pb, "block-wise dataflow can only help");
    assert!(bw < pb * 2.0, "at min size the gain is dataflow-only, must be modest");
}

#[test]
fn simulation_is_deterministic() {
    let d = driver();
    let a = d.run_strategy("block-wise", 172).unwrap().1;
    let b = d.run_strategy("block-wise", 172).unwrap().1;
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.layer_util, b.layer_util);
}

#[test]
fn dataflow_ablation_blockwise_alloc_layerwise_flow() {
    // Ablation (DESIGN.md §7 ablA): block-wise allocation only helps
    // fully when paired with the block-wise dataflow. With a layer-wise
    // plan, both dataflows are valid; block-wise flow must not be slower.
    let g = resnet18(32, 10);
    let map = map_network(&g, ArrayCfg::paper(), false);
    let acts = synth_activations(&g, &map, 1, 5, SynthCfg::default());
    let trace = trace_from_activations(&g, &map, &acts);
    let prof = NetworkProfile::from_trace(&map, &trace);
    let chip = ChipCfg::paper(172);
    let plan = StrategyRegistry::lookup_allocator("perf-based")
        .unwrap()
        .allocate(&map, &prof, chip.total_arrays())
        .unwrap();
    let placement = place(&map, &plan, &chip).unwrap();
    let lw = simulate(
        &chip, &map, &plan, &placement, &trace,
        SimCfg {
            mode: ReadMode::ZeroSkip,
            dataflow: &LAYER_WISE,
            engine: &cimfab::sim::engine::EVENT,
            images: 6,
            warmup: 1,
            write_latency_ns: 100.0,
            inject: None,
        },
    );
    let bw = simulate(
        &chip, &map, &plan, &placement, &trace,
        SimCfg {
            mode: ReadMode::ZeroSkip,
            dataflow: &BLOCK_WISE,
            engine: &cimfab::sim::engine::EVENT,
            images: 6,
            warmup: 1,
            write_latency_ns: 100.0,
            inject: None,
        },
    );
    assert!(
        bw.throughput_ips >= lw.throughput_ips * 0.999,
        "block-wise dataflow slower than layer-wise on the same plan: {} vs {}",
        bw.throughput_ips,
        lw.throughput_ips
    );
}

#[test]
fn busy_cycles_conserved_under_allocation() {
    // Total work is a property of the trace, not the plan: chip_util *
    // capacity must equal the same busy total for every ZS algorithm.
    let d = driver();
    let mut busys = vec![];
    for alloc in ["weight-based", "perf-based", "block-wise", "hybrid"] {
        let (plan, r) = d.run_strategy(alloc, 200).unwrap();
        let chip = ChipCfg::paper(200);
        // reconstruct total busy array-cycles from chip_util
        let capacity_arrays: usize = plan
            .duplicates
            .iter()
            .zip(&d.map.grids)
            .map(|(dups, g)| dups.iter().sum::<usize>() * g.arrays_per_block)
            .sum();
        let busy = r.chip_util * (capacity_arrays as f64) * r.makespan as f64;
        let _ = chip;
        busys.push(busy);
    }
    for w in busys.windows(2) {
        let rel = (w[0] - w[1]).abs() / w[0];
        assert!(rel < 1e-6, "busy cycles differ across allocations: {busys:?}");
    }
}

#[test]
fn minimal_plan_utilization_profile_is_unbalanced_weight_based() {
    // Fig 9's story: weight-based leaves some layers mostly idle.
    let d = driver();
    let (_, r) = d.run_strategy("weight-based", 258).unwrap();
    let max = r.layer_util.iter().cloned().fold(0.0, f64::max);
    let min = r.layer_util.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max > min * 2.0, "weight-based should be visibly unbalanced: {:?}", r.layer_util);

    let (_, rb) = d.run_strategy("block-wise", 258).unwrap();
    let mean_bw: f64 = rb.layer_util.iter().sum::<f64>() / rb.layer_util.len() as f64;
    let mean_wb: f64 = r.layer_util.iter().sum::<f64>() / r.layer_util.len() as f64;
    assert!(
        mean_bw > mean_wb,
        "block-wise mean utilization {mean_bw} should beat weight-based {mean_wb}"
    );
}

#[test]
fn plan_validates_and_places_at_every_sweep_size() {
    let d = driver();
    for pes in d.sweep_sizes(6) {
        let chip = ChipCfg::paper(pes);
        for alloc in PAPER_ALGORITHMS.iter().chain(&["hybrid"]) {
            let (plan, _) = d.run_strategy(alloc, pes).unwrap();
            plan.validate(&d.map, chip.total_arrays()).unwrap();
        }
    }
}

#[test]
fn arrays_never_exceed_budget_even_minimal() {
    let map = map_network(&resnet18(32, 10), ArrayCfg::paper(), false);
    let plan = AllocationPlan::minimal(&map);
    assert_eq!(plan.arrays_used(&map), 5472);
}
