//! End-to-end pins for the `cimfab serve` daemon (`cimfab::server`):
//!
//! * two concurrent jobs sharing a `PrefixSpec` prepare the prefix
//!   exactly once (pool misses == 1), and the pooled results are
//!   byte-identical to a serial batch `run_sweep` over the same
//!   scenarios;
//! * a cancelled job frees its queue slot (a previously-rejected submit
//!   succeeds after the cancel) without poisoning the pool (a later job
//!   on the same prefix still runs, on a pool hit);
//! * malformed request lines answer with an `error` line and leave the
//!   connection usable;
//! * a registered strategy that panics mid-scenario is isolated: the
//!   job reports a typed `error` line and a `done` with the failure
//!   counted, the worker slot is freed, and the daemon keeps serving;
//! * a submit with `timeout_ms` past its deadline terminates with
//!   `done … "timed_out":true` without running the remaining scenarios;
//! * `shutdown` over the wire stops the daemon with `Ok(())`, and a
//!   Unix-socket daemon removes its socket file on the way out.
//!
//! Tests bind TCP port 0 (the OS picks a free port) so parallel test
//! processes never collide; the Unix-socket path is exercised once,
//! under a pid-stamped temp path.

use cimfab::pipeline::{run_sweep, ScenarioBuilder, SweepCfg};
use cimfab::server::{Bind, ServeCfg, Server};
use cimfab::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;

/// Bind on a free port and serve on a background thread.
fn start(mut cfg: ServeCfg) -> (SocketAddr, JoinHandle<anyhow::Result<()>>) {
    cfg.bind = Bind::Tcp("127.0.0.1:0".into());
    let server = Server::bind(cfg).unwrap();
    let addr = server.tcp_addr().unwrap();
    let h = std::thread::spawn(move || server.run());
    (addr, h)
}

struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let w = TcpStream::connect(addr).unwrap();
        let r = BufReader::new(w.try_clone().unwrap());
        Client { w, r }
    }

    fn send(&mut self, line: &str) {
        self.w.write_all(line.as_bytes()).unwrap();
        self.w.write_all(b"\n").unwrap();
        self.w.flush().unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.r.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad JSON line {line:?}: {e}"))
    }

    /// Read lines until one with `"type": ty` arrives; returns every
    /// line read, the match last.
    fn recv_until(&mut self, ty: &str) -> Vec<Json> {
        let mut out = Vec::new();
        loop {
            let j = self.recv();
            let done = j.get("type").as_str() == Some(ty);
            out.push(j);
            if done {
                return out;
            }
        }
    }

    /// Read until this job's terminal `done` line.
    fn recv_job(&mut self, job: &str) -> Vec<Json> {
        let mut out = Vec::new();
        loop {
            let j = self.recv();
            let done =
                j.get("type").as_str() == Some("done") && j.get("job").as_str() == Some(job);
            out.push(j);
            if done {
                return out;
            }
        }
    }
}

fn shutdown(addr: SocketAddr, h: JoinHandle<anyhow::Result<()>>) {
    let mut c = Client::connect(addr);
    c.send(r#"{"op":"shutdown"}"#);
    assert_eq!(c.recv().get("type").as_str(), Some("shutting_down"));
    h.join().unwrap().unwrap();
}

/// The wire submit for `net=resnet18 res=32` with the crate-default
/// prefix knobs, so the shared prefix matches `base_builder()` exactly.
fn submit_line(id: &str, alloc: &str, images: usize) -> String {
    format!(
        r#"{{"op":"submit","id":"{id}","net":"resnet18","res":32,"scenarios":[{{"alloc":"{alloc}","pes":129,"images":{images}}}]}}"#
    )
}

/// The batch-side twin of [`submit_line`]'s prefix.
fn base_builder() -> ScenarioBuilder {
    ScenarioBuilder::new().net("resnet18").hw(32)
}

#[test]
fn concurrent_jobs_share_one_prepare_and_match_batch_sweep() {
    let (addr, h) = start(ServeCfg::new(Bind::Tcp(String::new())));

    // two clients submit jobs with the same prefix at the same instant
    let barrier = Arc::new(Barrier::new(2));
    let jobs = [("a", "baseline"), ("b", "block-wise")];
    let mut joins = Vec::new();
    for (id, alloc) in jobs {
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            barrier.wait();
            c.send(&submit_line(id, alloc, 4));
            let lines = c.recv_job(id);
            let done = lines.last().unwrap();
            assert_eq!(done.get("ok").as_u64(), Some(1), "{id}: {done:?}");
            assert_eq!(done.get("failed").as_u64(), Some(0));
            let result = lines
                .iter()
                .find(|l| l.get("type").as_str() == Some("result"))
                .unwrap_or_else(|| panic!("{id}: no result line in {lines:?}"));
            (result.get("report").compact(), result.get("prefix").as_str().unwrap().to_string())
        }));
    }
    let wire: Vec<(String, String)> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // the pool prepared the shared prefix exactly once; the other job
    // either joined the in-flight prepare or hit the finished entry
    let mut c = Client::connect(addr);
    c.send(r#"{"op":"stats"}"#);
    let stats = c.recv();
    let pool = stats.get("server").get("pool");
    assert_eq!(pool.get("misses").as_u64(), Some(1), "{stats:?}");
    assert_eq!(
        pool.get("hits").as_u64().unwrap() + pool.get("joins").as_u64().unwrap(),
        1,
        "{stats:?}"
    );
    assert_eq!(pool.get("failures").as_u64(), Some(0));
    for (_, status) in &wire {
        assert!(
            ["prepared", "pool-hit", "joined"].contains(&status.as_str()),
            "unexpected prefix status {status}"
        );
    }

    // byte-identical to a serial batch sweep over the same scenarios
    let scenarios: Vec<_> = jobs
        .iter()
        .map(|(_, alloc)| base_builder().alloc(*alloc).pes(129).sim_images(4).build().unwrap())
        .collect();
    let batch = run_sweep(&scenarios, &SweepCfg::serial()).unwrap();
    for ((wire_report, _), outcome) in wire.iter().zip(&batch) {
        assert_eq!(
            *wire_report,
            outcome.report_json().compact(),
            "served result diverged from the batch pipeline"
        );
    }

    shutdown(addr, h);
}

#[test]
fn cancelled_job_frees_its_slot_and_leaves_the_pool_clean() {
    // one worker + a one-slot queue makes admission observable: while
    // job "a" runs, exactly one job can wait in the queue
    let mut cfg = ServeCfg::new(Bind::Tcp(String::new()));
    cfg.workers = 1;
    cfg.queue_cap = 1;
    let (addr, h) = start(cfg);
    let mut c = Client::connect(addr);

    // "a" uses a dedicated prefix (seed 99) so its prepare keeps the
    // single worker busy while the queue dance below runs
    c.send(
        r#"{"op":"submit","id":"a","net":"resnet18","res":32,"seed":99,"profile_images":3,"scenarios":[{"alloc":"block-wise","pes":129,"images":8},{"alloc":"baseline","pes":129,"images":8}]}"#,
    );
    assert_eq!(c.recv().get("type").as_str(), Some("accepted"));

    // "b" fills the only queue slot; "c" must bounce
    c.send(&submit_line("b", "block-wise", 2));
    assert_eq!(c.recv().get("type").as_str(), Some("accepted"));
    c.send(&submit_line("c", "block-wise", 2));
    let rejected = c.recv();
    assert_eq!(rejected.get("type").as_str(), Some("error"), "{rejected:?}");
    assert!(rejected.get("message").as_str().unwrap().contains("queue full"), "{rejected:?}");

    // cancelling "b" frees the slot immediately — "d" is admitted
    // without waiting for a worker to reap the cancelled entry
    c.send(r#"{"op":"cancel","job":"b"}"#);
    let ack = c.recv_until("cancelled");
    assert_eq!(ack.last().unwrap().get("found").as_bool(), Some(true));
    c.send(&submit_line("d", "block-wise", 2));
    let lines = c.recv_until("accepted");
    assert_eq!(lines.last().unwrap().get("job").as_str(), Some("d"), "{lines:?}");

    // "b" terminates as cancelled with nothing run; "a" and "d" both
    // complete — the cancellation poisoned neither the queue nor the
    // pool ("d" shares the default-seed prefix, not a's)
    let mut done_b = None;
    let mut done_a = None;
    let mut done_d = None;
    while done_b.is_none() || done_a.is_none() || done_d.is_none() {
        let j = c.recv();
        if j.get("type").as_str() == Some("done") {
            match j.get("job").as_str() {
                Some("a") => done_a = Some(j),
                Some("b") => done_b = Some(j),
                Some("d") => done_d = Some(j),
                _ => {}
            }
        }
    }
    let b = done_b.unwrap();
    assert_eq!(b.get("cancelled").as_bool(), Some(true), "{b:?}");
    assert_eq!(b.get("ok").as_u64(), Some(0));
    assert_eq!(done_a.unwrap().get("ok").as_u64(), Some(2));
    assert_eq!(done_d.unwrap().get("ok").as_u64(), Some(1));

    shutdown(addr, h);
}

#[test]
fn malformed_lines_answer_error_and_keep_the_connection() {
    let (addr, h) = start(ServeCfg::new(Bind::Tcp(String::new())));
    let mut c = Client::connect(addr);

    for (line, needle) in [
        ("this is not json", "invalid request JSON"),
        (r#"{"op":"fly"}"#, "unknown op"),
        (r#"{"op":"submit","net":"resnet18"}"#, "scenarios"),
        (r#"{"op":"stats","bogus":1}"#, "unknown request field"),
    ] {
        c.send(line);
        let j = c.recv();
        assert_eq!(j.get("type").as_str(), Some("error"), "{line} -> {j:?}");
        assert!(j.get("message").as_str().unwrap().contains(needle), "{line} -> {j:?}");
    }

    // a semantically-bad submit is rejected per job, with the job id
    c.send(r#"{"op":"submit","id":"typo","net":"resnet19","scenarios":[{"pes":129}]}"#);
    let j = c.recv();
    assert_eq!(j.get("type").as_str(), Some("error"));
    assert_eq!(j.get("job").as_str(), Some("typo"));
    assert!(j.get("message").as_str().unwrap().contains("resnet18"), "{j:?}");

    // ... and the same connection still serves valid requests
    c.send(r#"{"op":"stats"}"#);
    let j = c.recv();
    assert_eq!(j.get("type").as_str(), Some("stats"));
    assert_eq!(j.get("server").get("rejected").as_u64(), Some(1), "{j:?}");

    shutdown(addr, h);
}

/// An allocation strategy that panics on use — registered globally so
/// the daemon accepts jobs naming it, then detonates inside the worker.
struct Panicky;

impl cimfab::alloc::Allocator for Panicky {
    fn name(&self) -> &str {
        "panicky"
    }
    fn describe(&self) -> &str {
        "deliberately panics (serve isolation test)"
    }
    fn allocate(
        &self,
        _map: &cimfab::mapping::NetworkMap,
        _profile: &cimfab::stats::NetworkProfile,
        _budget: usize,
    ) -> cimfab::Result<cimfab::mapping::AllocationPlan> {
        panic!("deliberate test panic");
    }
}

#[test]
fn panicking_strategy_is_isolated_and_frees_the_worker() {
    cimfab::strategy::StrategyRegistry::register_global(Some(&Panicky), None).unwrap();
    let mut cfg = ServeCfg::new(Bind::Tcp(String::new()));
    cfg.workers = 1; // the panic must free the only worker
    let (addr, h) = start(cfg);
    let mut c = Client::connect(addr);

    // one scenario panics, its sibling must still run
    c.send(
        r#"{"op":"submit","id":"p1","net":"resnet18","res":32,"scenarios":[{"alloc":"panicky","pes":129,"images":2},{"alloc":"block-wise","pes":129,"images":2}]}"#,
    );
    let lines = c.recv_job("p1");
    let err = lines
        .iter()
        .find(|l| l.get("type").as_str() == Some("error"))
        .unwrap_or_else(|| panic!("no error line in {lines:?}"));
    assert_eq!(err.get("job").as_str(), Some("p1"));
    assert!(err.get("message").as_str().unwrap().contains("panicked"), "{err:?}");
    assert!(err.get("message").as_str().unwrap().contains("deliberate test panic"), "{err:?}");
    let done = lines.last().unwrap();
    assert_eq!(done.get("ok").as_u64(), Some(1), "{done:?}");
    assert_eq!(done.get("failed").as_u64(), Some(1), "{done:?}");
    assert_ne!(done.get("cancelled").as_bool(), Some(true), "{done:?}");

    // the single worker survived the unwind: a fresh job completes
    c.send(&submit_line("p2", "block-wise", 2));
    let done = c.recv_job("p2");
    assert_eq!(done.last().unwrap().get("ok").as_u64(), Some(1), "{done:?}");

    shutdown(addr, h);
}

#[test]
fn expired_deadlines_terminate_jobs_as_timed_out() {
    let (addr, h) = start(ServeCfg::new(Bind::Tcp(String::new())));
    let mut c = Client::connect(addr);

    // a zero deadline is already past when the worker picks the job up:
    // no scenario runs, and the done line carries timed_out
    c.send(
        r#"{"op":"submit","id":"t1","timeout_ms":0,"net":"resnet18","res":32,"scenarios":[{"alloc":"baseline","pes":129,"images":2},{"alloc":"block-wise","pes":129,"images":2}]}"#,
    );
    let lines = c.recv_job("t1");
    let done = lines.last().unwrap();
    assert_eq!(done.get("timed_out").as_bool(), Some(true), "{done:?}");
    assert_eq!(done.get("ok").as_u64(), Some(0), "{done:?}");
    assert!(
        !lines.iter().any(|l| l.get("type").as_str() == Some("result")),
        "no scenario may run past the deadline: {lines:?}"
    );

    // a generous deadline does not trip, and its done line omits the key
    c.send(
        r#"{"op":"submit","id":"t2","timeout_ms":600000,"net":"resnet18","res":32,"scenarios":[{"alloc":"block-wise","pes":129,"images":2}]}"#,
    );
    let done = c.recv_job("t2");
    let done = done.last().unwrap();
    assert_eq!(done.get("ok").as_u64(), Some(1), "{done:?}");
    assert_eq!(done.get("timed_out").as_bool(), None, "{done:?}");

    shutdown(addr, h);
}

#[cfg(unix)]
#[test]
fn unix_socket_daemon_serves_and_cleans_up_its_socket_file() {
    use std::os::unix::net::UnixStream;

    let path = std::env::temp_dir().join(format!("cimfab-serve-test-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let server = Server::bind(ServeCfg::new(Bind::Unix(path.clone()))).unwrap();
    let h = std::thread::spawn(move || server.run());

    let w = UnixStream::connect(&path).unwrap();
    let mut r = BufReader::new(w.try_clone().unwrap());
    let send = |line: &str| {
        (&w).write_all(line.as_bytes()).unwrap();
        (&w).write_all(b"\n").unwrap();
    };
    let mut recv = || {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    send(&submit_line("u1", "block-wise", 2));
    assert_eq!(recv().get("type").as_str(), Some("accepted"));
    loop {
        let j = recv();
        if j.get("type").as_str() == Some("done") {
            assert_eq!(j.get("ok").as_u64(), Some(1), "{j:?}");
            break;
        }
    }
    send(r#"{"op":"shutdown"}"#);
    assert_eq!(recv().get("type").as_str(), Some("shutting_down"));
    h.join().unwrap().unwrap();
    assert!(!path.exists(), "daemon left its socket file behind");

    // a second daemon can bind the same path after the clean exit
    let server = Server::bind(ServeCfg::new(Bind::Unix(path.clone()))).unwrap();
    drop(server);
    let _ = std::fs::remove_file(&path);
}
