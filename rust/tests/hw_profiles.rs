//! Hardware-profile API guarantees:
//!
//! * **golden parity** — the `rram-128` profile drives the pipeline to
//!   byte-identical stage artifacts vs the pre-refactor
//!   `ArrayCfg::paper()` path, reconstructed here verbatim from the
//!   seed's literal constants;
//! * **validation** — propcheck over random spec knobs: `HwProfile`
//!   accepts exactly the valid combinations and rejects zero geometry,
//!   non-divisible cell bits, and variance budgets that overflow the
//!   ADC — as `Result`s, never panics;
//! * **serde round-trip** — every registered profile survives
//!   JSON → parse → JSON byte-for-byte;
//! * **openness** — a custom profile JSON on disk and a
//!   runtime-registered device/profile are immediately drivable through
//!   `--hw` semantics ([`ProfileRegistry::resolve`]) and the pipeline.

use cimfab::config::{ArrayCfg, ChipCfg};
use cimfab::hw::{ArraySpec, ChipSpec, DeviceModel, HwProfile, ProfileRegistry};
use cimfab::mapping::map_network;
use cimfab::pipeline::{self, artifact, ScenarioBuilder};
use cimfab::sim::{simulate, SimCfg};
use cimfab::stats::synth::{synth_activations, SynthCfg};
use cimfab::stats::{trace_from_activations, NetworkProfile};
use cimfab::strategy::StrategyRegistry;
use cimfab::util::propcheck;
use cimfab::xbar::variance;

/// The seed's `ArrayCfg::paper()` literal, reproduced verbatim.
fn pre_refactor_array() -> ArrayCfg {
    ArrayCfg {
        rows: 128,
        cols: 128,
        weight_bits: 8,
        input_bits: 8,
        adc_bits: 3,
        col_mux: 8,
        skip_empty_planes: true,
        cell_bits: 1,
    }
}

/// The seed's `ChipCfg::paper(pes)` literal, reproduced verbatim.
fn pre_refactor_chip(pes: usize) -> ChipCfg {
    ChipCfg {
        pes,
        arrays_per_pe: 64,
        clock_hz: 100e6,
        array: pre_refactor_array(),
        feature_packet_bytes: 128,
        psum_packet_bytes: 64,
        link_bytes_per_cycle: 32,
        router_latency: 1,
        pipeline_images: 8,
    }
}

#[test]
fn rram_128_lowering_matches_the_pre_refactor_literals() {
    let p = ProfileRegistry::lookup("rram-128").unwrap();
    assert_eq!(p.array_cfg().unwrap(), pre_refactor_array());
    assert_eq!(p.chip_cfg(172).unwrap(), pre_refactor_chip(172));
    // and the shims resolve through the profile
    assert_eq!(ArrayCfg::paper(), pre_refactor_array());
    assert_eq!(ChipCfg::paper(86), pre_refactor_chip(86));
}

#[test]
fn rram_128_pipeline_reproduces_pre_refactor_stage_artifacts_byte_for_byte() {
    // New path: the profile-threaded pipeline at the default profile.
    let spec = ScenarioBuilder::new()
        .net("resnet18")
        .hw(32)
        .profile_images(1)
        .seed(7)
        .prefix()
        .unwrap();
    assert_eq!(spec.hw_profile, "rram-128");
    let prep = pipeline::prepare(&spec, None).unwrap();
    let sc = ScenarioBuilder::from_prefix(&spec)
        .alloc("block-wise")
        .pes(172)
        .sim_images(4)
        .build()
        .unwrap();
    let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();

    // Old path: the seed's stage sequence with literal configs.
    let graph = pipeline::build_graph("resnet18", 32).unwrap();
    let map = map_network(&graph, pre_refactor_array(), false);
    let acts = synth_activations(&graph, &map, 1, 7, SynthCfg::default());
    let trace = trace_from_activations(&graph, &map, &acts);
    let profile = NetworkProfile::from_trace(&map, &trace);
    let chip = pre_refactor_chip(172);
    let allocator = StrategyRegistry::lookup_allocator("block-wise").unwrap();
    let flow = StrategyRegistry::lookup_dataflow("block-wise").unwrap();
    let plan = allocator.allocate(&map, &profile, chip.total_arrays()).unwrap();
    let placement = cimfab::mapping::place(&map, &plan, &chip).unwrap();
    let result =
        simulate(&chip, &map, &plan, &placement, &trace, SimCfg::for_strategy(allocator, flow, 4));

    // Byte-identical artifacts at every shared stage.
    assert_eq!(
        artifact::map_json(&prep.map).pretty(),
        artifact::map_json(&map).pretty(),
        "Map artifact diverged"
    );
    assert_eq!(
        artifact::trace_json(&prep.map, &prep.trace).pretty(),
        artifact::trace_json(&map, &trace).pretty(),
        "Trace artifact diverged"
    );
    assert_eq!(
        artifact::profile_json(&prep.profile).pretty(),
        artifact::profile_json(&profile).pretty(),
        "Profile artifact diverged"
    );
    assert_eq!(
        artifact::plan_json(&out.plan, &prep.map).pretty(),
        artifact::plan_json(&plan, &map).pretty(),
        "Allocate artifact diverged"
    );
    assert_eq!(
        artifact::sim_result_json(&out.result).pretty(),
        artifact::sim_result_json(&result).pretty(),
        "Simulate artifact diverged"
    );
}

#[test]
fn profile_validation_propcheck() {
    let devices: [&'static dyn DeviceModel; 3] = [
        ProfileRegistry::lookup_device("rram").unwrap(),
        ProfileRegistry::lookup_device("pcram").unwrap(),
        ProfileRegistry::lookup_device("sram").unwrap(),
    ];
    propcheck::check("HwProfile validation", 0x55AA, 150, |rng| {
        let rows = [0usize, 64, 100, 128, 256][rng.index(5)];
        let cols = [0usize, 64, 100, 128][rng.index(4)];
        let col_mux = [1usize, 7, 8, 16][rng.index(4)];
        let adc_bits_cap = [0usize, 3, 6][rng.index(3)];
        let ber_budget = [1e-3, 1e-30][rng.index(2)];
        let device = devices[rng.index(3)];
        let spec =
            ArraySpec { rows, cols, col_mux, adc_bits_cap, ber_budget, ..ArraySpec::default() };

        let cells_per_weight = 8 / device.cell_bits();
        let should_be_valid = rows >= 1
            && cols >= 1
            && cols % cells_per_weight == 0
            && cols % col_mux == 0
            && adc_bits_cap >= 1
            && variance::derive_adc_bits(device.variance(), ber_budget, rows, adc_bits_cap)
                .is_some();

        let built = HwProfile::new("prop", "propcheck case", device, spec, ChipSpec::default());
        cimfab::prop_assert!(
            built.is_ok() == should_be_valid,
            "rows={rows} cols={cols} mux={col_mux} cap={adc_bits_cap} ber={ber_budget:.0e} \
             dev={}: expected valid={should_be_valid}, got {built:?}",
            device.name()
        );
        Ok(())
    });
}

#[test]
fn every_registered_profile_roundtrips_through_json() {
    let profiles = ProfileRegistry::snapshot().profiles();
    assert!(profiles.len() >= 4, "expected the four built-ins at least");
    for p in profiles {
        let text = p.to_json().pretty();
        let back = HwProfile::from_json(&cimfab::util::json::Json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
        assert_eq!(back, p, "{} changed across the JSON round-trip", p.name);
        assert_eq!(back.to_json().pretty(), text, "{} re-emits differently", p.name);
        assert_eq!(back.array_cfg().unwrap(), p.array_cfg().unwrap());
    }
}

#[test]
fn registry_covers_at_least_three_device_technologies() {
    let reg = ProfileRegistry::snapshot();
    let mut techs: Vec<&str> = reg.devices().iter().map(|d| d.name()).collect();
    techs.sort_unstable();
    techs.dedup();
    assert!(techs.len() >= 3, "list-hw must report >= 3 technologies, got {techs:?}");
    // and the profiles actually span them
    let mut used: Vec<String> =
        reg.profiles().iter().map(|p| p.device.name().to_string()).collect();
    used.sort_unstable();
    used.dedup();
    assert!(used.len() >= 3, "built-in profiles span {used:?}");
}

#[test]
fn custom_json_profile_drives_the_pipeline_end_to_end() {
    let dir = std::env::temp_dir().join(format!("cimfab_hw_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("skinny-rram.json");
    std::fs::write(
        &path,
        r#"{
  "name": "skinny-rram",
  "description": "64-column RRAM variant defined in userland JSON",
  "device": "rram",
  "array": { "cols": 64, "col_mux": 8 },
  "chip": { "arrays_per_pe": 128 }
}
"#,
    )
    .unwrap();
    let path_str = path.to_str().unwrap().to_string();

    // resolve() accepts the path directly (the --hw grammar)
    let p = ProfileRegistry::resolve(&path_str).unwrap();
    assert_eq!(p.name, "skinny-rram");
    assert_eq!(p.array_cfg().unwrap().cols, 64);
    assert_eq!(p.array_cfg().unwrap().adc_bits, 3, "derivation is device-, not file-, driven");

    // and the whole pipeline runs on it
    let spec = ScenarioBuilder::new()
        .net("resnet18")
        .hw(32)
        .hw_profile(path_str.clone())
        .profile_images(1)
        .seed(5)
        .prefix()
        .unwrap();
    assert_eq!(spec.hw_profile, path_str, "paths are not canonicalized away");
    assert_ne!(spec.id(), spec.id().replace("skinny-rram", ""), "profile tags the prefix id");
    let prep = pipeline::prepare(&spec, None).unwrap();
    assert_eq!(prep.hw.name, "skinny-rram");
    // 64-wide arrays halve the weight columns per array => more arrays
    assert!(prep.map.min_arrays() > 5472 / 2, "skinny arrays need more tiles");
    let sc = ScenarioBuilder::from_prefix(&spec)
        .alloc("block-wise")
        .pes(prep.min_pes() * 2)
        .sim_images(4)
        .build()
        .unwrap();
    let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();
    assert!(out.result.throughput_ips > 0.0);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prefix_specs_differing_only_in_hardware_do_not_share_a_prefix() {
    let base = ScenarioBuilder::new()
        .net("resnet18")
        .hw(32)
        .profile_images(1)
        .seed(9)
        .prefix()
        .unwrap();
    let mut sram = base.clone();
    sram.hw_profile = "sram-128".into();
    assert_ne!(base.id(), sram.id(), "hardware must split the sweep's prefix cache");
    assert!(sram.id().contains("sram-128"), "{}", sram.id());
}

/// A userland device registered at runtime: ferroelectric-ish, 4 bits
/// per cell, modest variance.
struct FeFet;

impl DeviceModel for FeFet {
    fn name(&self) -> &str {
        "fefet-test"
    }
    fn describe(&self) -> &str {
        "4-bit/cell test device"
    }
    fn cell_bits(&self) -> usize {
        4
    }
    fn variance(&self) -> f64 {
        0.03
    }
    fn read_energy_pj(&self) -> f64 {
        0.05
    }
    fn write_energy_pj(&self) -> f64 {
        5.0
    }
    fn write_latency_ns(&self) -> f64 {
        50.0
    }
    fn leakage_pw(&self) -> f64 {
        900_000.0
    }
}

#[test]
fn runtime_registered_device_and_profile_drive_the_pipeline() {
    ProfileRegistry::register_global_device(&FeFet).unwrap();
    // 4-bit cells: 2 cells per weight; 3% variance sustains 16-row reads
    let profile = HwProfile::new(
        "fefet-128",
        "runtime-registered test profile",
        &FeFet,
        ArraySpec::default(),
        ChipSpec::default(),
    )
    .unwrap();
    assert_eq!(profile.array_cfg().unwrap().adc_bits, 4, "3% variance sustains 16-row reads");
    ProfileRegistry::register_global(profile).unwrap();
    // duplicate registration is rejected
    assert!(ProfileRegistry::register_global_device(&FeFet).is_err());

    let sc = ScenarioBuilder::new()
        .net("resnet18")
        .hw(32)
        .hw_profile("fefet-128")
        .profile_images(1)
        .alloc("hybrid")
        .pes(120)
        .sim_images(4)
        .build()
        .unwrap();
    let prep = pipeline::prepare(&sc.prefix, None).unwrap();
    assert_eq!(prep.map.array.cell_bits, 4);
    let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();
    assert_eq!(out.plan.algorithm, "hybrid");
    assert!(out.result.throughput_ips > 0.0);
}
