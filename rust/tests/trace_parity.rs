//! Golden parity: the packed bit-plane trace fast path must be
//! **bit-identical** to the retained seed implementation
//! (`stats::trace::reference`) — every per-(image, layer, patch, block)
//! duration, every density numerator/denominator — across random
//! geometries (dense conv, depthwise block-diagonal, linear) and the
//! real paper workloads.

use cimfab::config::ArrayCfg;
use cimfab::dnn::{mobilenet, resnet18, Graph, Op};
use cimfab::mapping::map_network;
use cimfab::pipeline::artifact;
use cimfab::stats::synth::{synth_activations, SynthCfg};
use cimfab::stats::trace::reference::trace_from_activations_reference;
use cimfab::stats::{trace_from_activations, trace_from_activations_threads, NetworkProfile};
use cimfab::tensor::Tensor;
use cimfab::util::prng::Prng;
use cimfab::util::propcheck;

#[test]
fn packed_trace_matches_reference_on_random_geometries() {
    propcheck::check("packed trace == reference", 0x7ACE, 48, |rng| {
        let kind = rng.below(4);
        let (graph, include_linear) = if kind == 3 {
            let f = 1 + rng.below(500) as usize;
            let mut g = Graph::new("lin", [f, 1, 1]);
            g.push("fc", Op::Linear { in_features: f, out_features: 1 + rng.below(64) as usize });
            (g, true)
        } else {
            let k = [1usize, 2, 3, 3, 5, 7][rng.below(6) as usize];
            let stride = 1 + rng.below(3) as usize;
            let pad = rng.below(k as u64 + 1) as usize;
            // keep h + 2*pad >= k so the im2col output is non-empty
            let h = k.saturating_sub(2 * pad).max(1) + rng.below(10) as usize;
            let w = k.saturating_sub(2 * pad).max(1) + rng.below(10) as usize;
            let c = 1 + rng.below(24) as usize;
            let mut g = Graph::new("conv", [c, h, w]);
            if kind == 2 {
                g.push("dw", Op::DwConv { ch: c, k, stride, pad });
            } else {
                let out_ch = 1 + rng.below(32) as usize;
                g.push("c", Op::Conv { in_ch: c, out_ch, k, stride, pad });
            }
            (g, false)
        };
        let map = map_network(&graph, ArrayCfg::paper(), include_linear);
        let mut data_rng = Prng::new(rng.next_u64());
        let images = 1 + rng.below(2) as usize;
        let acts: Vec<Vec<Tensor<u8>>> = (0..images)
            .map(|_| {
                map.grids
                    .iter()
                    .map(|gr| {
                        let shape = graph.layers[gr.graph_idx].in_shape;
                        Tensor::from_fn(&shape.to_vec(), |_| data_rng.next_u32() as u8)
                    })
                    .collect()
            })
            .collect();
        let threads = 1 + rng.below(4) as usize;
        let fast = trace_from_activations_threads(&graph, &map, &acts, threads);
        let reference = trace_from_activations_reference(&graph, &map, &acts);
        cimfab::prop_assert!(
            fast == reference,
            "trace diverged (kind {kind}, {} grids, {images} images, {threads} threads)",
            map.grids.len()
        );
        Ok(())
    });
}

#[test]
fn fig4_fig6_densities_unchanged_on_resnet18() {
    // the Figs 4 & 6 inputs are block_ones / block_bits and the profile
    // aggregates — all must be exactly what the seed path produced
    let graph = resnet18(32, 10);
    let map = map_network(&graph, ArrayCfg::paper(), false);
    let acts = synth_activations(&graph, &map, 2, 7, SynthCfg::default());
    let fast = trace_from_activations(&graph, &map, &acts);
    let reference = trace_from_activations_reference(&graph, &map, &acts);
    assert_eq!(fast, reference);
    for img in 0..acts.len() {
        for (lf, lr) in fast.images[img].layers.iter().zip(&reference.images[img].layers) {
            assert_eq!(lf.block_ones, lr.block_ones);
            assert_eq!(lf.block_bits, lr.block_bits);
        }
    }
    let pf = NetworkProfile::from_trace(&map, &fast);
    let pr = NetworkProfile::from_trace(&map, &reference);
    assert_eq!(
        artifact::profile_json(&pf).compact(),
        artifact::profile_json(&pr).compact(),
        "profile artifact (Figs 4 & 6 source) diverged"
    );
    assert_eq!(
        artifact::trace_json(&map, &fast).compact(),
        artifact::trace_json(&map, &reference).compact(),
        "trace artifact diverged"
    );
}

#[test]
fn mobilenet_depthwise_blocks_stay_bit_identical() {
    let graph = mobilenet(32, 10);
    let map = map_network(&graph, ArrayCfg::paper(), false);
    assert!(map.grids.iter().any(|g| g.diagonal), "expected depthwise grids");
    let acts = synth_activations(&graph, &map, 1, 11, SynthCfg::default());
    let fast = trace_from_activations(&graph, &map, &acts);
    let reference = trace_from_activations_reference(&graph, &map, &acts);
    assert_eq!(fast, reference);
}

#[test]
fn synthetic_activation_traces_match_across_thread_counts() {
    let graph = resnet18(32, 10);
    let map = map_network(&graph, ArrayCfg::paper(), false);
    let acts = synth_activations(&graph, &map, 2, 3, SynthCfg::default());
    let one = trace_from_activations_threads(&graph, &map, &acts, 1);
    let many = trace_from_activations_threads(&graph, &map, &acts, 8);
    assert_eq!(one, many);
}
