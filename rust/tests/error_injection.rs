//! Seeded error-injection suite: the Monte Carlo fault model must agree
//! with the §III-A analytic rate, inject nothing at σ=0, keep every
//! fault-free artifact byte-identical, and leave the `varaware`
//! allocator indistinguishable from `block-wise` on uniform ones
//! distributions.
//!
//! Statistical assertions go through
//! `cimfab::util::propcheck::check_stat` with a 3σ bound, so with the
//! pinned seed (`CIMFAB_TEST_SEED`, default 7) they are deterministic
//! and with any other seed they fail with probability < 0.3%.

use cimfab::alloc::{greedy, varaware::VARAWARE, Allocator};
use cimfab::config::ArrayCfg;
use cimfab::dnn::resnet18;
use cimfab::mapping::{map_network, NetworkMap};
use cimfab::pipeline::{self, artifact, PrefixSpec, ScenarioBuilder, StatsSource};
use cimfab::stats::synth::{synth_activations, SynthCfg};
use cimfab::stats::{trace_from_activations, NetworkProfile};
use cimfab::util::json::Json;
use cimfab::util::prng::Prng;
use cimfab::util::propcheck;
use cimfab::xbar::variance::read_error_rate;
use cimfab::xbar::{ReadMode, SubArray};

/// CI pins `CIMFAB_TEST_SEED=7`; any other value still passes with
/// probability ≥ 99.7% per statistical assertion (3σ bounds).
fn test_seed() -> u64 {
    std::env::var("CIMFAB_TEST_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}

fn spec() -> PrefixSpec {
    PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed: 7,
        artifacts_dir: "artifacts".into(),
    }
}

fn setup() -> (NetworkMap, NetworkProfile) {
    let g = resnet18(32, 10);
    let map = map_network(&g, ArrayCfg::paper(), false);
    let acts = synth_activations(&g, &map, 2, 17, SynthCfg::default());
    let trace = trace_from_activations(&g, &map, &acts);
    let prof = NetworkProfile::from_trace(&map, &trace);
    (map, prof)
}

#[test]
fn injected_flip_rate_matches_the_variance_model() {
    // All-0xFF weights put a '1' in every bit plane, and driving exactly
    // k = 4 of the 128 word lines makes every ADC conversion sum to 4 —
    // mid-range for the 3-bit ADC (adc_rows = 8), so clamping never
    // hides an upward flip and the per-conversion flip probability is
    // exactly `read_error_rate(4, σ) = 2·Q(0.5/(2σ))`.
    let cfg = ArrayCfg::paper();
    let k = 4usize;
    assert!(k < cfg.adc_rows(), "k must stay below adc_rows to avoid clamping");
    let weights = vec![-1i8; cfg.rows * cfg.weight_cols()];
    let conversions_per_call = (cfg.weight_bits * cfg.weight_cols()) as u64;
    let sa = SubArray::program(cfg, &weights);
    let mut x = vec![0u8; cfg.rows];
    for xi in x.iter_mut().take(k) {
        *xi = 1; // value 1 ⇒ only input bit-plane 0 is active under zero-skip
    }

    let mut root = Prng::new(test_seed());
    let trials = 400u64;
    let mut flips_by_sigma = Vec::new();
    for &sigma in &[0.10f64, 0.15] {
        let (mut conversions, mut flips) = (0u64, 0u64);
        for t in 0..trials {
            let mut rng = root.fork(t);
            let (psums, _, tally) = sa.matvec_inject(&x, ReadMode::ZeroSkip, sigma, &mut rng);
            assert_eq!(psums.len(), sa.cfg().weight_cols());
            assert_eq!(
                tally.conversions, conversions_per_call,
                "one batch of 4 rows × 8 weight planes × 16 weight columns"
            );
            conversions += tally.conversions;
            flips += tally.flips;
        }
        assert!(flips > 0, "σ={sigma} must flip some codes over {conversions} conversions");
        let p = read_error_rate(k, sigma);
        let measured = flips as f64 / conversions as f64;
        let se = (p * (1.0 - p) / conversions as f64).sqrt();
        propcheck::check_stat(
            &format!("sub-array flip rate @ σ={sigma}"),
            measured,
            p,
            se,
            3.0,
        );
        flips_by_sigma.push(flips);
    }
    assert!(
        flips_by_sigma[1] > flips_by_sigma[0],
        "flip counts must grow with σ: {flips_by_sigma:?}"
    );
}

#[test]
fn sigma_zero_is_byte_identical_to_the_fault_free_path() {
    propcheck::check("matvec_inject(σ=0) == matvec", 0x51_60, 40, |rng| {
        let cfg = ArrayCfg::paper();
        let rows = 1 + rng.index(cfg.rows);
        let wcols = cfg.weight_cols();
        let w: Vec<i8> = (0..rows * wcols).map(|_| rng.next_u32() as i8).collect();
        let x: Vec<u8> = (0..rows).map(|_| rng.next_u32() as u8).collect();
        let mode = if rng.index(2) == 0 { ReadMode::ZeroSkip } else { ReadMode::Baseline };
        let sa = SubArray::program(cfg, &w);
        let (want_psums, want_cycles) = sa.matvec(&x, mode);
        // two identical streams: one goes through the injector, then
        // both must produce the same next draw — σ=0 consumes nothing
        let mut used = rng.fork(1);
        let mut untouched = used.clone();
        let (psums, cycles, tally) = sa.matvec_inject(&x, mode, 0.0, &mut used);
        cimfab::prop_assert!(psums == want_psums, "σ=0 psums diverged");
        cimfab::prop_assert!(cycles == want_cycles, "σ=0 cycles diverged");
        cimfab::prop_assert!(
            tally.conversions == 0 && tally.flips == 0,
            "σ=0 must tally nothing, got {tally:?}"
        );
        cimfab::prop_assert!(
            used.next_u64() == untouched.next_u64(),
            "σ=0 must not draw from the PRNG"
        );
        Ok(())
    });
}

#[test]
fn pipeline_ber_matches_the_analytic_rate_on_both_engines() {
    // Every block of a non-derated plan reads full adc_rows-wide
    // batches, so the run's BER is a Binomial(reads, p)/reads sample
    // with p = read_error_rate(adc_rows, σ) exactly.
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let sigma = 0.10;
    let p = read_error_rate(ArrayCfg::paper().adc_rows(), sigma);
    for engine in ["event", "stepped"] {
        let sc = ScenarioBuilder::from_prefix(&spec())
            .alloc("block-wise")
            .engine(engine)
            .pes(prep.min_pes() * 2)
            .sim_images(2)
            .inject_errors(test_seed())
            .fault_sigma(sigma)
            .build()
            .unwrap();
        let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();
        let e = out.result.errors.as_ref().expect("injection must report ErrorStats");
        assert!(e.reads > 0 && e.flipped > 0, "{engine}: σ=0.1 must flip codes");
        assert!(e.worst_ber >= e.ber, "{engine}: the worst block can't beat the mean");
        let se = (p * (1.0 - p) / e.reads as f64).sqrt();
        propcheck::check_stat(&format!("{engine} network BER @ σ={sigma}"), e.ber, p, se, 3.0);
    }
}

#[test]
fn injection_off_keeps_artifacts_byte_identical() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let base = ScenarioBuilder::from_prefix(&spec())
        .alloc("block-wise")
        .pes(prep.min_pes() * 2)
        .sim_images(2);

    // fault-free run: no `_err` id segment, no errors key, no read_rows
    let off = base.clone().build().unwrap();
    assert!(!off.id().contains("_err"), "{}", off.id());
    let off_out = pipeline::run_scenario(&prep.view(), &off, None).unwrap();
    assert!(off_out.result.errors.is_none());
    assert!(off_out.plan.read_rows.is_none());
    let off_json = artifact::sim_result_json(&off_out.result).pretty();
    assert!(!off_json.contains("\"errors\""), "{off_json}");
    assert!(!artifact::plan_json(&off_out.plan, &prep.map).pretty().contains("read_rows"));

    // σ=0 injection: the errors object appears but accounts zero flips,
    // and every other key matches the fault-free artifact byte for byte
    let zero = base.clone().inject_errors(test_seed()).fault_sigma(0.0).build().unwrap();
    let zero_out = pipeline::run_scenario(&prep.view(), &zero, None).unwrap();
    let e = zero_out.result.errors.as_ref().expect("seeded runs always report ErrorStats");
    assert!(e.reads > 0, "σ=0 still counts conversions");
    assert_eq!(e.flipped, 0, "σ=0 must inject nothing");
    assert_eq!(e.ber, 0.0);
    let mut stripped = artifact::sim_result_json(&zero_out.result);
    if let Json::Obj(m) = &mut stripped {
        m.remove("errors").expect("σ=0 artifact must carry the errors object");
    }
    assert_eq!(
        stripped.pretty(),
        off_json,
        "σ=0 injection changed a fault-free artifact byte"
    );
}

#[test]
fn varaware_at_uniform_density_is_byte_identical_to_block_wise() {
    // With a uniform ones distribution nothing derates, so `varaware`
    // must delegate to the base block-wise water-filling exactly — the
    // same identity `pooled@1.0` pins in tests/weight_pools.rs.
    let (map, mut prof) = setup();
    for layer in prof.block_density.iter_mut() {
        for d in layer.iter_mut() {
            *d = 0.25;
        }
    }
    propcheck::check("varaware@uniform == block-wise", 0x7A2A, 20, |rng| {
        let budget = map.min_arrays() + rng.index(map.min_arrays() * 2 + 1);
        let got = VARAWARE.allocate(&map, &prof, budget).unwrap();
        cimfab::prop_assert!(got.read_rows.is_none(), "uniform density must not derate");
        let mut want = greedy::blockwise(&map, &prof.block_cycles, budget).unwrap();
        want.algorithm = "varaware".into();
        cimfab::prop_assert!(
            artifact::plan_json(&got, &map).pretty() == artifact::plan_json(&want, &map).pretty(),
            "varaware diverged from block-wise at budget {budget}"
        );
        Ok(())
    });
}

#[test]
fn varaware_derated_widths_cut_the_per_read_error_rate() {
    // Bimodal ones densities force derating; every derated width must
    // validate against the plan rules (power-of-two divisor of
    // adc_rows) and strictly cut the §III-A per-conversion flip
    // probability the injection accountant charges that block — the
    // accuracy side of the accuracy/latency trade the bench sweeps.
    let (map, mut prof) = setup();
    for layer in prof.block_density.iter_mut() {
        for (r, d) in layer.iter_mut().enumerate() {
            *d = if r % 2 == 0 { 0.05 } else { 0.5 };
        }
    }
    let budget = map.min_arrays() * 2;
    let plan = VARAWARE.allocate(&map, &prof, budget).unwrap();
    plan.validate(&map, budget).unwrap();
    let rr = plan.read_rows.as_ref().expect("skewed densities must derate");
    let full = map.array.adc_rows();
    let sigma = 0.10;
    let full_rate = read_error_rate(full, sigma);
    let mut derated = 0usize;
    for &w in rr.iter().flatten() {
        if w < full {
            derated += 1;
            assert!(
                read_error_rate(w, sigma) < full_rate,
                "width {w} must err less often than {full}"
            );
        }
    }
    assert!(derated > 0, "no block was derated");
}
