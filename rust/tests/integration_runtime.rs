//! Integration: the PJRT runtime against the AOT artifacts.
//!
//! These tests need `make artifacts` to have run; they are skipped (with
//! a loud message) when the artifacts are missing so `cargo test` still
//! works in a fresh checkout.

use cimfab::config::ArrayCfg;
use cimfab::runtime::{CimKernel, Engine, GoldenModel, Manifest};
use cimfab::tensor::Tensor;
use cimfab::util::bitops;
use cimfab::util::prng::Prng;
use cimfab::xbar::{ReadMode, SubArray};

fn manifest() -> Option<Manifest> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the `pjrt` feature — runtime stubs cannot execute");
        return None;
    }
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            None
        }
    }
}

#[test]
fn golden_model_runs_and_shapes_match() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    for net in ["resnet18", "vgg11"] {
        let model = GoldenModel::load(&engine, &m, net).unwrap();
        let (acts, logits) = model.run(&GoldenModel::gen_image(model.meta.hw, 1)).unwrap();
        assert_eq!(acts.len(), model.meta.conv_layers.len());
        assert_eq!(logits.len(), model.meta.num_classes);
        assert!(logits.iter().all(|l| l.is_finite()));
        for (a, c) in acts.iter().zip(&model.meta.conv_layers) {
            assert_eq!(a.shape()[0], c.in_ch, "{net}/{}", c.name);
        }
    }
}

#[test]
fn golden_outputs_are_deterministic() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let model = GoldenModel::load(&engine, &m, "vgg11").unwrap();
    let img = GoldenModel::gen_image(model.meta.hw, 2);
    let (a1, l1) = model.run(&img).unwrap();
    let (a2, l2) = model.run(&img).unwrap();
    assert_eq!(l1, l2);
    for (x, y) in a1.iter().zip(&a2) {
        assert_eq!(x.data(), y.data());
    }
}

#[test]
fn pallas_kernel_equals_rust_subarray_bit_exactly() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let kernel = CimKernel::load(&engine, &m).unwrap();
    let mut rng = Prng::new(0xBEEF);
    for trial in 0..3 {
        let xs: Vec<u8> =
            (0..kernel.patches * kernel.rows).map(|_| rng.next_u32() as u8).collect();
        let ws: Vec<i8> = (0..kernel.rows * kernel.cols).map(|_| rng.next_u32() as i8).collect();
        let got = kernel.matmul(&xs, &ws).unwrap();
        let mut cfg = ArrayCfg::paper();
        cfg.cols = kernel.cols * cfg.weight_bits;
        let sa = SubArray::program(cfg, &ws);
        let mut want = Vec::new();
        for p in 0..kernel.patches {
            want.extend(
                sa.matvec(&xs[p * kernel.rows..(p + 1) * kernel.rows], ReadMode::ZeroSkip).0,
            );
        }
        assert_eq!(got, want, "trial {trial}");
    }
}

#[test]
fn golden_activation_densities_are_plausible() {
    // The L2 model's statistics must support the paper's premise: the
    // stem sees dense pixels, deep layers see sparse activations.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let model = GoldenModel::load(&engine, &m, "resnet18").unwrap();
    let (acts, _) = model.run(&GoldenModel::gen_image(model.meta.hw, 3)).unwrap();
    let density = |t: &Tensor<u8>| bitops::bit_density(t.data());
    let stem = density(&acts[0]);
    let deep: Vec<f64> = acts[4..].iter().map(density).collect();
    let deep_mean = deep.iter().sum::<f64>() / deep.len() as f64;
    assert!(stem > 0.25, "stem density {stem} not pixel-like");
    assert!(deep_mean < stem, "deep layers ({deep_mean}) must be sparser than stem ({stem})");
}

#[test]
fn golden_stats_drive_the_full_driver() {
    let Some(_) = manifest() else { return };
    use cimfab::coordinator::{Driver, DriverOpts, StatsSource};
    let d = Driver::prepare(DriverOpts {
        net: "vgg11".into(),
        hw: 32,
        stats: StatsSource::Golden,
        profile_images: 1,
        sim_images: 4,
        seed: 5,
        ..DriverOpts::default()
    })
    .unwrap();
    let results = d.run_all(d.min_pes() * 2).unwrap();
    let bw = results.iter().find(|(a, _)| a == "block-wise").unwrap().1.throughput_ips;
    assert!(bw > 0.0);
}
