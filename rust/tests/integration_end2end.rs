//! End-to-end smoke over the whole stack + live-dispatch correctness.

use cimfab::config::ArrayCfg;
use cimfab::coordinator::dispatch::run_conv_blockwise;
use cimfab::coordinator::{Driver, DriverOpts, StatsSource};
use cimfab::tensor::Tensor;
use cimfab::util::prng::Prng;

#[test]
fn both_networks_full_pipeline_synthetic() {
    for (net, hw) in [("resnet18", 32usize), ("vgg11", 32), ("mobilenet", 32)] {
        let d = Driver::prepare(DriverOpts {
            net: net.into(),
            hw,
            stats: StatsSource::Synthetic,
            profile_images: 1,
            sim_images: 4,
            seed: 3,
            ..DriverOpts::default()
        })
        .unwrap();
        let results = d.run_all(d.min_pes() * 2).unwrap();
        assert_eq!(results.len(), 4);
        for (alloc, r) in &results {
            assert!(
                r.throughput_ips > 0.0 && r.throughput_ips.is_finite(),
                "{net}/{alloc}: bad throughput"
            );
            assert!(r.chip_util > 0.0 && r.chip_util <= 1.0);
            assert!(
                r.noc.peak_link_utilization < 1.0,
                "{net}/{alloc}: NoC saturated ({:.2})",
                r.noc.peak_link_utilization
            );
        }
    }
}

#[test]
fn live_dispatch_verifies_many_shapes() {
    let mut rng = Prng::new(0xD15);
    let cases = [
        (8usize, 4usize, 6usize, 1usize, vec![1usize]),
        (16, 8, 8, 1, vec![2, 1]),
        (32, 8, 6, 2, vec![1, 2, 1]),
    ];
    for (cin, cout, hw, stride, dups) in cases {
        let input: Tensor<u8> = Tensor::from_fn(&[cin, hw, hw], |_| (rng.next_u32() as u8) & 0x7F);
        let weights: Tensor<i8> = Tensor::from_fn(&[cout, cin, 3, 3], |_| rng.next_u32() as i8);
        let r = run_conv_blockwise(&ArrayCfg::paper(), &input, &weights, stride, 1, &dups)
            .unwrap();
        assert!(r.verified, "cin={cin} cout={cout} hw={hw} stride={stride}");
    }
}

#[test]
fn fig_tables_render_from_driver() {
    let d = Driver::prepare(DriverOpts {
        net: "resnet18".into(),
        hw: 32,
        stats: StatsSource::Synthetic,
        profile_images: 1,
        sim_images: 4,
        seed: 8,
        ..DriverOpts::default()
    })
    .unwrap();
    let fig4 = cimfab::report::fig4_table(&d.map, &d.profile).render();
    assert_eq!(fig4.lines().count(), 2 + 20, "one row per conv layer");
    // fig6 layers exist: 9-block and 18-block layers
    assert!(d.map.grids.iter().any(|g| g.blocks_per_copy == 9));
    assert!(d.map.grids.iter().any(|g| g.blocks_per_copy == 18));
    let results = d.run_all(129).unwrap();
    let summary = cimfab::report::speedup_summary(&results).render();
    assert!(summary.contains("block-wise"));
}

#[test]
fn cli_binary_help_runs() {
    // `cimfab` with no args prints help and exits 0 — checks the binary
    // links and the CLI parser behaves.
    let exe = env!("CARGO_BIN_EXE_cimfab");
    let out = std::process::Command::new(exe).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("USAGE"), "unexpected help text: {text}");
}

#[test]
fn cli_list_strategies_prints_the_registry() {
    let exe = env!("CARGO_BIN_EXE_cimfab");
    let out = std::process::Command::new(exe).arg("list-strategies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["baseline", "weight-based", "perf-based", "block-wise", "hybrid"] {
        assert!(text.contains(name), "missing strategy '{name}' in:\n{text}");
    }
    assert!(text.contains("layer-wise"), "missing dataflow section:\n{text}");
    for engine in ["event", "stepped"] {
        assert!(text.contains(engine), "missing engine '{engine}' in:\n{text}");
    }
}

#[test]
fn cli_unknown_engine_suggests_the_closest_name() {
    let exe = env!("CARGO_BIN_EXE_cimfab");
    let out = std::process::Command::new(exe)
        .args(["simulate", "--net", "resnet18", "--res", "32", "--engine", "evnt"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("did you mean 'event'?"), "unexpected error: {text}");
}

#[test]
fn cli_simulate_mobilenet_with_explicit_engine() {
    let exe = env!("CARGO_BIN_EXE_cimfab");
    let out = std::process::Command::new(exe)
        .args([
            "simulate", "--net", "mobilenet", "--res", "32", "--engine", "event", "--alloc",
            "block-wise", "--images", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("event engine"), "engine missing from report line:\n{text}");
    assert!(text.contains("inferences/s"), "{text}");
}

#[test]
fn cli_unknown_strategy_suggests_the_closest_name() {
    let exe = env!("CARGO_BIN_EXE_cimfab");
    let out = std::process::Command::new(exe)
        .args(["simulate", "--net", "resnet18", "--hw", "32", "--alloc", "blok-wise"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("did you mean 'block-wise'?"), "unexpected error: {text}");
}

#[test]
fn cli_variance_subcommand() {
    let exe = env!("CARGO_BIN_EXE_cimfab");
    let out = std::process::Command::new(exe).arg("variance").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rows/read"));
}
