//! Streaming JSON layer vs the retained DOM: the pull-based reader must
//! accept exactly the documents `Json::parse` accepts (and produce the
//! same values), the incremental writer must emit the exact bytes of
//! `Json::pretty`/`Json::compact`, and a damaged prefix-cache entry —
//! truncated at any byte, corrupted mid-stream, or trailed by garbage —
//! must degrade to a clean miss, never a panic or a wrong answer.

use cimfab::pipeline::{
    self, cache, prepare_cached, CacheStatus, PrefixCache, PrefixSpec, StatsSource,
};
use cimfab::util::json::Json;
use cimfab::util::json_stream::{JsonReader, JsonWriter};
use cimfab::util::prng::Prng;
use cimfab::util::propcheck;

// ---------------------------------------------------------------------------
// random document generator
// ---------------------------------------------------------------------------

/// Characters that stress the escape paths: quotes, backslashes, the
/// named control escapes, `\u`-only control bytes, and multi-byte UTF-8
/// up to an astral-plane code point.
const STRING_POOL: &[char] = &[
    'a', 'Z', '0', ' ', '_', '"', '\\', '/', '\n', '\t', '\r', '\u{8}', '\u{c}', '\u{1}',
    '\u{1f}', 'é', 'π', '日', '\u{2028}', '𝄞',
];

fn gen_string(rng: &mut Prng) -> String {
    let len = rng.index(10);
    (0..len).map(|_| STRING_POOL[rng.index(STRING_POOL.len())]).collect()
}

/// Numbers spanning every representation branch: exact u64/i64 edges,
/// values past 2^53 where f64 loses integers, and assorted floats.
fn gen_number(rng: &mut Prng) -> Json {
    match rng.index(10) {
        0 => Json::num(u64::MAX),
        1 => Json::num(u64::MAX - 1),
        2 => Json::num(i64::MIN),
        3 => Json::num((1u64 << 53) + 1),
        4 => Json::num(rng.next_u64()),
        5 => Json::num(rng.next_u64() as i64),
        6 => Json::num(0u64),
        7 => Json::num(rng.f64() * 1e6 - 5e5),
        8 => Json::num(rng.normal()),
        _ => Json::num(rng.f64()),
    }
}

fn gen_scalar(rng: &mut Prng) -> Json {
    match rng.index(5) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::str(&gen_string(rng)),
        _ => gen_number(rng),
    }
}

fn gen_value(rng: &mut Prng, depth: usize) -> Json {
    if depth == 0 || rng.chance(0.5) {
        return gen_scalar(rng);
    }
    if rng.chance(0.5) {
        Json::arr((0..rng.index(5)).map(|_| gen_value(rng, depth - 1)))
    } else {
        let pairs: Vec<(String, Json)> =
            (0..rng.index(5)).map(|_| (gen_string(rng), gen_value(rng, depth - 1))).collect();
        Json::obj(pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
    }
}

/// ASCII-only variant (printable chars plus escapes) whose serialized
/// bytes can be mutated at any offset and stay valid UTF-8.
fn gen_ascii_value(rng: &mut Prng, depth: usize) -> Json {
    const ASCII: &[char] = &['a', 'B', '7', ' ', '"', '\\', ',', ':', '[', '}', '\n'];
    let gen_str = |rng: &mut Prng| -> String {
        (0..rng.index(8)).map(|_| ASCII[rng.index(ASCII.len())]).collect()
    };
    if depth == 0 || rng.chance(0.5) {
        return match rng.index(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::str(&gen_str(rng)),
            _ => Json::num(rng.next_u64() as i64),
        };
    }
    if rng.chance(0.5) {
        Json::arr((0..rng.index(4)).map(|_| gen_ascii_value(rng, depth - 1)))
    } else {
        let pairs: Vec<(String, Json)> =
            (0..rng.index(4)).map(|_| (gen_str(rng), gen_ascii_value(rng, depth - 1))).collect();
        Json::obj(pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
    }
}

/// A raw number token with randomized shape: optional sign, 1–20 digits
/// (overflowing u64 on purpose), optional ragged fraction (possibly no
/// digits after the dot), optional exponent (possibly empty).
fn gen_number_token(rng: &mut Prng) -> String {
    let mut tok = String::new();
    if rng.chance(0.3) {
        tok.push('-');
    }
    let int_digits = 1 + rng.index(20);
    for _ in 0..int_digits {
        tok.push(char::from(b'0' + rng.index(10) as u8));
    }
    if rng.chance(0.4) {
        tok.push('.');
        for _ in 0..rng.index(3) {
            tok.push(char::from(b'0' + rng.index(10) as u8));
        }
    }
    if rng.chance(0.3) {
        tok.push(if rng.chance(0.5) { 'e' } else { 'E' });
        if rng.chance(0.5) {
            tok.push(if rng.chance(0.5) { '+' } else { '-' });
        }
        for _ in 0..rng.index(3) {
            tok.push(char::from(b'0' + rng.index(10) as u8));
        }
    }
    tok
}

/// Both parsers on the same text: same acceptance, same value.
fn assert_parity(text: &str) -> Result<(), String> {
    let dom = Json::parse(text);
    let streamed = JsonReader::parse_document(text.as_bytes());
    match (dom, streamed) {
        (Ok(d), Ok(s)) => {
            cimfab::prop_assert!(d == s, "values diverged on {text:?}: dom={d:?} streamed={s:?}");
        }
        (Err(_), Err(_)) => {}
        (d, s) => {
            cimfab::prop_assert!(false, "acceptance diverged on {text:?}: {d:?} vs {s:?}");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// reader / writer parity properties
// ---------------------------------------------------------------------------

#[test]
fn reader_matches_dom_on_random_documents() {
    propcheck::check("streaming reader == Json::parse", 0x5EED_1, 300, |rng| {
        let v = gen_value(rng, 3);
        assert_parity(&v.pretty())?;
        assert_parity(&v.compact())?;
        // leading/trailing whitespace tolerance must match too
        assert_parity(&format!("  \n\t{} \n", v.compact()))
    });
}

#[test]
fn reader_matches_dom_on_ragged_number_tokens() {
    propcheck::check("ragged number tokens", 0x5EED_2, 500, |rng| {
        let doc = format!("[{},{}]", gen_number_token(rng), gen_number_token(rng));
        assert_parity(&doc)
    });
}

#[test]
fn reader_matches_dom_on_mutated_documents() {
    propcheck::check("mutated documents", 0x5EED_3, 400, |rng| {
        let v = gen_ascii_value(rng, 3);
        let text = if rng.chance(0.5) { v.pretty() } else { v.compact() };
        let mut bytes = text.into_bytes();
        match rng.index(3) {
            // truncate at a random offset
            0 => bytes.truncate(rng.index(bytes.len() + 1)),
            // overwrite one byte with a structural character
            1 => {
                if !bytes.is_empty() {
                    let structural = [b',', b'}', b']', b'"', b'x', b'{', b':', b'0'];
                    let i = rng.index(bytes.len());
                    bytes[i] = structural[rng.index(structural.len())];
                }
            }
            // insert a stray comma
            _ => {
                let i = rng.index(bytes.len() + 1);
                bytes.insert(i, b',');
            }
        }
        let text = String::from_utf8(bytes).expect("ascii mutations stay utf-8");
        assert_parity(&text)
    });
}

#[test]
fn writer_matches_dom_rendering_on_random_values() {
    propcheck::check("streaming writer == pretty/compact", 0x5EED_4, 300, |rng| {
        let v = gen_value(rng, 3);
        let mut w = JsonWriter::pretty(Vec::new());
        w.value(&v).unwrap();
        let streamed = String::from_utf8(w.finish().unwrap()).unwrap();
        cimfab::prop_assert!(
            streamed == v.pretty(),
            "pretty bytes diverged:\nstreamed: {streamed}\ndom:      {}",
            v.pretty()
        );
        let mut w = JsonWriter::compact(Vec::new());
        w.value(&v).unwrap();
        let streamed = String::from_utf8(w.finish().unwrap()).unwrap();
        cimfab::prop_assert!(
            streamed == v.compact(),
            "compact bytes diverged:\nstreamed: {streamed}\ndom:      {}",
            v.compact()
        );
        Ok(())
    });
}

#[test]
fn u64_edge_integers_round_trip_through_the_stream() {
    for n in [u64::MAX, u64::MAX - 1, (1u64 << 53) + 1, 1u64 << 63] {
        let doc = Json::obj(vec![("n", Json::num(n))]);
        let mut w = JsonWriter::compact(Vec::new());
        w.value(&doc).unwrap();
        let bytes = w.finish().unwrap();
        assert_eq!(bytes, doc.compact().into_bytes());
        let back = JsonReader::parse_document(&bytes).unwrap();
        assert_eq!(back.get("n").as_u64(), Some(n), "u64 fidelity lost at {n}");
    }
    let doc = Json::obj(vec![("n", Json::num(i64::MIN))]);
    let back = JsonReader::parse_document(doc.compact().as_bytes()).unwrap();
    assert_eq!(back.get("n").as_i64(), Some(i64::MIN));
}

// ---------------------------------------------------------------------------
// damaged cache entries degrade to misses
// ---------------------------------------------------------------------------

#[test]
fn damaged_cache_entries_degrade_to_misses() {
    let dir = std::env::temp_dir()
        .join(format!("cimfab_json_stream_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = PrefixCache::new(dir.to_str().unwrap()).unwrap();
    let spec = PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed: 11,
        artifacts_dir: "artifacts".into(),
    };
    let (cold, st) = prepare_cached(&spec, None, Some(&store)).unwrap();
    assert_eq!(st, CacheStatus::Miss);
    let key = cache::key(&spec).unwrap();
    let entry = store.entry_path(&spec, &key);
    let full = std::fs::read(&entry).unwrap();
    assert!(store.load(&spec, &key, true).is_some(), "pristine entry must hit");

    // truncation at assorted offsets: empty file, inside the version
    // header, inside the trace payload, one byte short of complete
    let n = full.len();
    for cut in [0, 1, 7, n / 5, n / 3, n / 2, 3 * n / 4, n - 2, n - 1] {
        std::fs::write(&entry, &full[..cut]).unwrap();
        assert!(
            store.load(&spec, &key, true).is_none(),
            "entry truncated at byte {cut}/{n} must read as a miss"
        );
    }

    // mid-stream corruption inside the net_trace section: the document
    // stays structurally plausible for a while, then a key mismatches
    let pos = full
        .windows(11)
        .position(|w| w == b"\"net_trace\"")
        .expect("entry stores a net_trace section");
    let mut corrupt = full.clone();
    corrupt[pos + 15] = b'x';
    std::fs::write(&entry, &corrupt).unwrap();
    assert!(store.load(&spec, &key, true).is_none(), "corrupted trace key must miss");

    // trailing garbage after a complete document is rejected
    let mut trailing = full.clone();
    trailing.extend_from_slice(b"{}");
    std::fs::write(&entry, &trailing).unwrap();
    assert!(store.load(&spec, &key, true).is_none(), "trailing garbage must miss");

    // the pipeline recomputes through the damage and repairs the entry
    std::fs::write(&entry, &full[..n / 2]).unwrap();
    let (re, st) = prepare_cached(&spec, None, Some(&store)).unwrap();
    assert_eq!(st, CacheStatus::Miss, "truncated entry must degrade to a miss");
    assert_eq!(re.trace, cold.trace, "recompute after damage must match the cold run");
    let (warm, st) = prepare_cached(&spec, None, Some(&store)).unwrap();
    assert_eq!(st, CacheStatus::Hit, "the recompute must have repaired the entry");
    assert_eq!(warm.trace, cold.trace);
    assert_eq!(
        pipeline::artifact::profile_json(&warm.profile).compact(),
        pipeline::artifact::profile_json(&cold.profile).compact()
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
