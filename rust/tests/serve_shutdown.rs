//! SIGTERM drain for the `cimfab serve` daemon — in its own test
//! binary because the daemon's termination flag (and the installed
//! signal handler) are process-wide: once this test raises `SIGTERM`,
//! no other daemon test could run in the same process.
//!
//! Pins the graceful half of the shutdown contract: a signal arriving
//! mid-flight lets the running job drain to a normal `done` line,
//! rejects submits that race the shutdown with a typed error, and
//! removes the Unix socket file before `run()` returns `Ok`.

#![cfg(unix)]

use cimfab::server::{Bind, ServeCfg, Server};
use cimfab::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;

extern "C" {
    fn raise(sig: i32) -> i32;
}
const SIGTERM: i32 = 15;

#[test]
fn sigterm_drains_in_flight_work_rejects_new_submits_and_removes_the_socket() {
    let path =
        std::env::temp_dir().join(format!("cimfab-serve-sigterm-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut cfg = ServeCfg::new(Bind::Unix(path.clone()));
    cfg.workers = 1;
    let server = Server::bind(cfg).unwrap();
    let h = std::thread::spawn(move || server.run());

    let w = UnixStream::connect(&path).unwrap();
    let mut r = BufReader::new(w.try_clone().unwrap());
    let send = |line: &str| {
        (&w).write_all(line.as_bytes()).unwrap();
        (&w).write_all(b"\n").unwrap();
    };
    let mut recv = || {
        let mut line = String::new();
        let n = r.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad JSON line {line:?}: {e}"))
    };

    // a two-scenario job; waiting for the first result line puts the
    // signal squarely mid-flight
    send(
        r#"{"op":"submit","id":"drain","net":"resnet18","res":32,"seed":41,"scenarios":[{"alloc":"block-wise","pes":129,"images":6},{"alloc":"baseline","pes":129,"images":6}]}"#,
    );
    assert_eq!(recv().get("type").as_str(), Some("accepted"));
    loop {
        if recv().get("type").as_str() == Some("result") {
            break;
        }
    }

    unsafe {
        raise(SIGTERM);
    }
    // give the accept loop (25 ms poll) time to observe the signal and
    // close the queue before the racing submit below
    std::thread::sleep(std::time::Duration::from_millis(400));

    send(
        r#"{"op":"submit","id":"late","net":"resnet18","res":32,"scenarios":[{"alloc":"baseline","pes":129,"images":2}]}"#,
    );

    // the in-flight job drains to a clean done; the late submit bounces
    let (mut drained, mut rejected) = (None, None);
    while drained.is_none() || rejected.is_none() {
        let j = recv();
        match j.get("type").as_str() {
            Some("done") if j.get("job").as_str() == Some("drain") => drained = Some(j),
            Some("error") => rejected = Some(j),
            _ => {}
        }
    }
    let done = drained.unwrap();
    assert_eq!(done.get("ok").as_u64(), Some(2), "in-flight job must drain fully: {done:?}");
    assert_eq!(done.get("failed").as_u64(), Some(0), "{done:?}");
    assert_ne!(done.get("cancelled").as_bool(), Some(true), "{done:?}");
    let err = rejected.unwrap();
    assert_eq!(err.get("job").as_str(), Some("late"), "{err:?}");
    assert!(err.get("message").as_str().unwrap().contains("shutting down"), "{err:?}");

    // run() returns Ok and the socket file is gone
    h.join().unwrap().unwrap();
    assert!(!path.exists(), "daemon left its socket file behind after SIGTERM");
}
