//! Pipeline determinism and parallel-equivalence guarantees:
//!
//! * the same `Scenario` + seed dumps byte-identical per-stage JSON
//!   artifacts across runs;
//! * the multi-threaded sweep executor produces results identical to a
//!   serial run;
//! * the pipeline-backed `Driver` matches the raw pipeline stages.

use cimfab::pipeline::artifact;
use cimfab::pipeline::{
    run_sweep, PrefixSpec, Scenario, ScenarioBuilder, Stage, StatsSource, SweepCfg,
};
use cimfab::strategy::PAPER_ALGORITHMS;
use std::collections::BTreeMap;
use std::path::Path;

fn spec(seed: u64) -> PrefixSpec {
    PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed,
        artifacts_dir: "artifacts".into(),
    }
}

fn scenario(seed: u64, alloc: &str, pes: usize) -> Scenario {
    ScenarioBuilder::from_prefix(&spec(seed)).alloc(alloc).pes(pes).sim_images(4).build().unwrap()
}

fn scenarios(seed: u64) -> Vec<Scenario> {
    let mut out = Vec::new();
    for pes in [129usize, 172] {
        for alloc in PAPER_ALGORITHMS.iter().chain(&["hybrid"]) {
            out.push(scenario(seed, alloc, pes));
        }
    }
    out
}

fn tmp_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("cimfab_dumps_{}_{tag}", std::process::id()));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    dir.to_str().unwrap().to_string()
}

/// Collect `relative-path → bytes` for every file under `root`.
fn read_tree(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_str().unwrap().to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

#[test]
fn stage_dumps_are_byte_identical_across_runs() {
    let scs = scenarios(13);
    let (da, db) = (tmp_dir("a"), tmp_dir("b"));
    run_sweep(&scs, &SweepCfg { threads: 1, dump_dir: Some(da.clone()), cache_dir: None }).unwrap();
    run_sweep(&scs, &SweepCfg { threads: 4, dump_dir: Some(db.clone()), cache_dir: None }).unwrap();

    let ta = read_tree(Path::new(&da));
    let tb = read_tree(Path::new(&db));
    assert!(!ta.is_empty(), "no dumps written");
    let keys_a: Vec<&String> = ta.keys().collect();
    let keys_b: Vec<&String> = tb.keys().collect();
    assert_eq!(keys_a, keys_b, "dump trees differ in file sets");
    for (path, bytes) in &ta {
        assert_eq!(bytes, &tb[path], "dump {path} differs between runs");
    }

    std::fs::remove_dir_all(&da).unwrap();
    std::fs::remove_dir_all(&db).unwrap();
}

#[test]
fn dump_tree_has_every_stage_exactly_once_per_scope() {
    let scs = scenarios(29);
    let dir = tmp_dir("tree");
    let cfg = SweepCfg { threads: 2, dump_dir: Some(dir.clone()), cache_dir: None };
    run_sweep(&scs, &cfg).unwrap();
    let tree = read_tree(Path::new(&dir));

    let prefix_id = spec(29).id();
    for stage in Stage::ALL {
        if stage.is_prefix() {
            let path = format!("{prefix_id}/{}", stage.dump_file());
            assert!(tree.contains_key(&path), "missing prefix dump {path}");
        } else {
            for sc in &scs {
                let path = format!("{prefix_id}/{}/{}", sc.id(), stage.dump_file());
                assert!(tree.contains_key(&path), "missing scenario dump {path}");
            }
        }
    }
    // 5 prefix files + 4 per scenario, nothing else
    assert_eq!(tree.len(), 5 + 4 * scs.len());
    // the new hybrid strategy dumps under its own historical-form id
    assert!(
        tree.keys().any(|k| k.contains("hybrid_pes129_img4")),
        "{:?}",
        tree.keys().collect::<Vec<_>>()
    );

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn parallel_sweep_matches_serial_bit_for_bit() {
    let scs = scenarios(7);
    let serial =
        run_sweep(&scs, &SweepCfg { threads: 1, dump_dir: None, cache_dir: None }).unwrap();
    let parallel =
        run_sweep(&scs, &SweepCfg { threads: 4, dump_dir: None, cache_dir: None }).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.scenario, p.scenario, "outcome order changed");
        assert_eq!(s.plan, p.plan, "{}: plans differ", s.scenario.id());
        // full-result comparison through the canonical JSON artifact
        assert_eq!(
            artifact::sim_result_json(&s.result).pretty(),
            artifact::sim_result_json(&p.result).pretty(),
            "{}: simulation results differ",
            s.scenario.id()
        );
    }
}

#[test]
fn sweep_reproduces_the_driver_path() {
    use cimfab::coordinator::{Driver, DriverOpts};
    let d = Driver::prepare(DriverOpts {
        net: "resnet18".into(),
        hw: 32,
        stats: StatsSource::Synthetic,
        profile_images: 1,
        sim_images: 4,
        seed: 13,
        ..DriverOpts::default()
    })
    .unwrap();
    let cfg = SweepCfg { threads: 3, dump_dir: None, cache_dir: None };
    let outcomes = run_sweep(&scenarios(13), &cfg).unwrap();
    for o in &outcomes {
        let (_, want) = d.run_strategy(&o.scenario.alloc, o.scenario.pes).unwrap();
        assert_eq!(o.result.makespan, want.makespan, "{}", o.scenario.id());
        assert_eq!(o.result.layer_util, want.layer_util, "{}", o.scenario.id());
    }
}

#[test]
fn synthetic_prefixes_differing_only_in_artifacts_dir_share_one_prefix() {
    // artifacts_dir is irrelevant under synthetic stats, so PrefixSpec::id()
    // ignores it and the executor must not prepare (or dump) twice.
    let a = spec(31);
    let mut b = spec(31);
    b.artifacts_dir = "elsewhere".into();
    assert_eq!(a.id(), b.id());
    let mk = |prefix: PrefixSpec, alloc: &str, dataflow: &str| Scenario {
        prefix,
        alloc: alloc.into(),
        dataflow: dataflow.into(),
        engine: "event".into(),
        pes: 172,
        sim_images: 4,
        oversub: 1.0,
        inject_seed: None,
        fault_sigma: None,
    };
    let scs = vec![mk(a, "weight-based", "layer-wise"), mk(b, "block-wise", "block-wise")];
    let dir = tmp_dir("shared");
    let cfg = SweepCfg { threads: 2, dump_dir: Some(dir.clone()), cache_dir: None };
    let out = run_sweep(&scs, &cfg).unwrap();
    assert_eq!(out.len(), 2);
    let tree = read_tree(Path::new(&dir));
    // one prefix directory (5 stage files) + two scenario dirs (4 each)
    assert_eq!(tree.len(), 5 + 2 * 4, "{:?}", tree.keys().collect::<Vec<_>>());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn multi_prefix_sweep_prepares_each_prefix_once_and_stays_ordered() {
    // Two nets in one sweep: outcomes must come back in input order with
    // the right prefixes attached.
    let mut scs = Vec::new();
    for net in ["resnet18", "vgg11"] {
        let prefix = PrefixSpec {
            net: net.into(),
            hw: 32,
            hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
            stats: StatsSource::Synthetic,
            profile_images: 1,
            seed: 3,
            artifacts_dir: "artifacts".into(),
        };
        for (alloc, dataflow) in [("weight-based", "layer-wise"), ("block-wise", "block-wise")] {
            scs.push(Scenario {
                prefix: prefix.clone(),
                alloc: alloc.into(),
                dataflow: dataflow.into(),
                engine: "event".into(),
                pes: 200,
                sim_images: 4,
                oversub: 1.0,
                inject_seed: None,
                fault_sigma: None,
            });
        }
    }
    let out = run_sweep(&scs, &SweepCfg { threads: 4, dump_dir: None, cache_dir: None }).unwrap();
    assert_eq!(out.len(), 4);
    for (o, sc) in out.iter().zip(&scs) {
        assert_eq!(&o.scenario, sc);
        assert!(o.result.throughput_ips > 0.0, "{}", sc.id());
    }
}
