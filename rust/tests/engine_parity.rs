//! Golden engine-parity suite: the event-driven engine must be
//! **bit-identical** to the cycle-stepped reference on cycle counts,
//! utilization, and NoC statistics, for every built-in allocation
//! strategy × every dataflow it can legally run, on the Fig 8 ResNet18
//! scenario.
//!
//! The comparison goes through the canonical simulate-stage JSON
//! artifact (`pipeline::artifact::sim_result_json`), the same encoding
//! the pipeline-determinism suite pins, so any drift in makespan,
//! per-layer stage cycles, utilization, throughput, or NoC counters
//! fails loudly with the diverging scenario's id.

use cimfab::pipeline::{self, artifact, PrefixSpec, ScenarioBuilder, StatsSource};
use cimfab::strategy::StrategyRegistry;

fn spec() -> PrefixSpec {
    PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed: 7,
        artifacts_dir: "artifacts".into(),
    }
}

/// Every (strategy, dataflow) pairing the builder accepts: uniform-plan
/// strategies run both dataflows; block-granular plans only the
/// barrier-free one.
fn legal_pairings() -> Vec<(String, String)> {
    let reg = StrategyRegistry::snapshot();
    let mut out = Vec::new();
    for a in reg.allocators() {
        for d in reg.dataflows() {
            if !d.requires_uniform_plan() || a.uniform_plans() {
                out.push((a.name().to_string(), d.name().to_string()));
            }
        }
    }
    out
}

#[test]
fn event_engine_matches_stepped_reference_on_fig8_resnet18() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let pes = prep.min_pes() * 2; // the paper's 172-PE Fig 8/9 point
    let pairings = legal_pairings();
    assert!(pairings.len() >= 8, "expected all built-in pairings, got {pairings:?}");
    for (alloc, dataflow) in pairings {
        let base = ScenarioBuilder::from_prefix(&spec())
            .alloc(&alloc)
            .dataflow(&dataflow)
            .pes(pes)
            .sim_images(2);
        let ev = base.clone().engine("event").build().unwrap();
        let st = base.clone().engine("stepped").build().unwrap();
        assert_ne!(ev.id(), st.id(), "engine must be part of the scenario id");
        let ev_out = pipeline::run_scenario(&prep.view(), &ev, None).unwrap();
        let st_out = pipeline::run_scenario(&prep.view(), &st, None).unwrap();
        assert_eq!(
            ev_out.plan, st_out.plan,
            "{alloc}+{dataflow}: allocation must not depend on the engine"
        );
        assert_eq!(
            artifact::sim_result_json(&ev_out.result).pretty(),
            artifact::sim_result_json(&st_out.result).pretty(),
            "{alloc}+{dataflow}: event engine diverged from the stepped reference"
        );
    }
}

#[test]
fn pooled_engines_agree_at_every_oversubscription_ratio() {
    // Golden pooled parity on the Fig 8 ResNet18 scenario: shrink the
    // chip by the oversubscription ratio so the weight-pool schedule
    // actually swaps mid-run, and require both engines to agree on the
    // full simulate artifact (makespan, stalls, reload counters) at
    // 1x (pooling off), 2x, and 4x.
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let min_pes = prep.min_pes();
    for oversub in [1.0f64, 2.0, 4.0] {
        let pes = (min_pes as f64 / oversub).ceil() as usize;
        let base = ScenarioBuilder::from_prefix(&spec())
            .alloc("pooled")
            .pes(pes)
            .sim_images(2)
            .oversub(oversub);
        let ev = base.clone().engine("event").build().unwrap();
        let st = base.clone().engine("stepped").build().unwrap();
        let ev_out = pipeline::run_scenario(&prep.view(), &ev, None).unwrap();
        let st_out = pipeline::run_scenario(&prep.view(), &st, None).unwrap();
        assert_eq!(
            ev_out.plan, st_out.plan,
            "pooled @{oversub}x: allocation must not depend on the engine"
        );
        assert_eq!(
            artifact::sim_result_json(&ev_out.result).pretty(),
            artifact::sim_result_json(&st_out.result).pretty(),
            "pooled @{oversub}x: event engine diverged from the stepped reference"
        );
        if oversub > 1.0 {
            assert!(
                ev_out.result.reloads >= 1,
                "pooled @{oversub}x: the shrunken chip should need at least one reload"
            );
            assert!(ev_out.plan.pools.is_some());
        } else {
            assert_eq!(ev_out.result.reloads, 0, "pooling must stay off at 1x");
            assert!(ev_out.plan.pools.is_none());
        }
    }
}

#[test]
fn engines_agree_under_error_injection() {
    // The injection accountant runs outside both engines over the same
    // trace and plan, so the full simulate artifact — ErrorStats block
    // included — must stay bit-identical between engines, for both the
    // plain block-wise plan and the derating varaware plan.
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let pes = prep.min_pes() * 2;
    for alloc in ["block-wise", "varaware"] {
        let base = ScenarioBuilder::from_prefix(&spec())
            .alloc(alloc)
            .pes(pes)
            .sim_images(2)
            .inject_errors(7)
            .fault_sigma(0.05);
        let ev = base.clone().engine("event").build().unwrap();
        let st = base.clone().engine("stepped").build().unwrap();
        assert!(ev.id().ends_with("_err7_fs0.05"), "{}", ev.id());
        let ev_out = pipeline::run_scenario(&prep.view(), &ev, None).unwrap();
        let st_out = pipeline::run_scenario(&prep.view(), &st, None).unwrap();
        assert_eq!(
            ev_out.plan, st_out.plan,
            "{alloc} under injection: allocation must not depend on the engine"
        );
        assert_eq!(
            artifact::sim_result_json(&ev_out.result).pretty(),
            artifact::sim_result_json(&st_out.result).pretty(),
            "{alloc} under injection: event engine diverged from the stepped reference"
        );
        let e = ev_out.result.errors.as_ref().expect("injection must report ErrorStats");
        assert!(e.reads > 0 && e.flipped > 0, "{alloc}: σ=0.05 must flip some codes");
    }
}

#[test]
fn parity_holds_on_the_depthwise_workload() {
    // MobileNet exercises the block-diagonal grids; parity must hold
    // there too (one strategy per dataflow family keeps this fast).
    let mut s = spec();
    s.net = "mobilenet".into();
    let prep = pipeline::prepare(&s, None).unwrap();
    let pes = prep.min_pes() * 2;
    for (alloc, dataflow) in [("perf-based", "layer-wise"), ("block-wise", "block-wise")] {
        let base =
            ScenarioBuilder::from_prefix(&s).alloc(alloc).dataflow(dataflow).pes(pes).sim_images(2);
        let ev = pipeline::run_scenario(&prep.view(), &base.clone().build().unwrap(), None)
            .unwrap();
        let st = pipeline::run_scenario(
            &prep.view(),
            &base.clone().engine("stepped").build().unwrap(),
            None,
        )
        .unwrap();
        assert_eq!(
            artifact::sim_result_json(&ev.result).pretty(),
            artifact::sim_result_json(&st.result).pretty(),
            "{alloc}+{dataflow} on mobilenet: engines diverged"
        );
    }
}

#[test]
fn stepped_engine_is_selectable_end_to_end() {
    // the full outcome (report stage included) works under --engine
    // stepped, and the scenario id records the non-default engine
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let sc = ScenarioBuilder::from_prefix(&spec())
        .alloc("block-wise")
        .engine("stepped")
        .pes(prep.min_pes())
        .sim_images(2)
        .build()
        .unwrap();
    assert!(sc.id().ends_with("_stepped"), "{}", sc.id());
    let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();
    assert!(out.result.throughput_ips > 0.0);
    assert_eq!(out.scenario.engine, "stepped");
}
