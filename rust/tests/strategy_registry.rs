//! Registry/API guarantees:
//!
//! * **parity** — every built-in `Allocator` produces a byte-identical
//!   `AllocationPlan` artifact (golden JSON via `pipeline::artifact`)
//!   to its pre-refactor enum path, reconstructed here verbatim from
//!   the seed's `match` arms over `alloc::greedy`;
//! * **builder validation** — propcheck over random knob combinations:
//!   `ScenarioBuilder` accepts exactly the valid ones and rejects zero
//!   budgets / missing nets / zero image counts;
//! * **openness** — a custom strategy registered at runtime is
//!   immediately drivable through the builder and the pipeline.

use cimfab::alloc::{greedy, Allocator};
use cimfab::config::ArrayCfg;
use cimfab::dnn::resnet18;
use cimfab::mapping::{map_network, AllocationPlan, NetworkMap};
use cimfab::pipeline::{self, artifact, PrefixSpec, ScenarioBuilder, StatsSource};
use cimfab::stats::synth::{synth_activations, SynthCfg};
use cimfab::stats::{trace_from_activations, NetworkProfile};
use cimfab::strategy::{StrategyRegistry, PAPER_ALGORITHMS};
use cimfab::util::propcheck;

fn setup() -> (NetworkMap, NetworkProfile) {
    let g = resnet18(32, 10);
    let map = map_network(&g, ArrayCfg::paper(), false);
    let acts = synth_activations(&g, &map, 2, 17, SynthCfg::default());
    let trace = trace_from_activations(&g, &map, &acts);
    let prof = NetworkProfile::from_trace(&map, &trace);
    (map, prof)
}

#[test]
fn registry_allocators_match_pre_refactor_enum_paths_byte_for_byte() {
    let (map, prof) = setup();
    for budget in [map.min_arrays(), map.min_arrays() * 2, map.min_arrays() * 3 + 7] {
        // The seed's `alloc::allocate` match arms, reproduced literally.
        let golden: [(&str, AllocationPlan); 4] = [
            ("baseline", greedy::layerwise(&map, &prof.layer_baseline_cycles, budget).unwrap()),
            ("weight-based", greedy::layerwise(&map, &prof.layer_baseline_cycles, budget).unwrap()),
            ("perf-based", greedy::layerwise(&map, &prof.layer_barrier_cycles, budget).unwrap()),
            ("block-wise", greedy::blockwise(&map, &prof.block_cycles, budget).unwrap()),
        ];
        for (name, mut want) in golden {
            want.algorithm = name.to_string();
            let got = StrategyRegistry::lookup_allocator(name)
                .unwrap()
                .allocate(&map, &prof, budget)
                .unwrap();
            assert_eq!(
                artifact::plan_json(&got, &map).pretty(),
                artifact::plan_json(&want, &map).pretty(),
                "{name} @ budget {budget}: registry plan diverged from the enum path"
            );
        }
    }
}

#[test]
fn all_registered_allocators_produce_valid_plans() {
    let (map, prof) = setup();
    let budget = map.min_arrays() * 2;
    let reg = StrategyRegistry::snapshot();
    let allocators = reg.allocators();
    assert!(allocators.len() >= 5);
    for a in allocators {
        let plan = a.allocate(&map, &prof, budget).unwrap();
        plan.validate(&map, budget).unwrap();
        assert_eq!(plan.algorithm, a.name());
        // the declared uniformity contract holds
        if a.uniform_plans() {
            assert!(plan.is_layerwise(), "{} claims uniform plans", a.name());
        }
    }
}

#[test]
fn builder_validation_propcheck() {
    propcheck::check("ScenarioBuilder validation", 0xB01D, 80, |rng| {
        let nets = ["resnet18", "resnet34", "vgg11", "mobilenet", "", "alexnet"];
        let net = nets[rng.index(nets.len())];
        let pes = rng.index(400); // 0 is invalid
        let sim_images = rng.index(6); // 0 is invalid
        let profile_images = rng.index(4); // 0 is invalid
        let allocs = ["baseline", "weight-based", "perf-based", "block-wise", "hybrid", "bogus"];
        let alloc = allocs[rng.index(allocs.len())];
        let built = ScenarioBuilder::new()
            .net(net)
            .pes(pes)
            .sim_images(sim_images)
            .profile_images(profile_images)
            .alloc(alloc)
            .build();
        let should_be_valid = ["resnet18", "resnet34", "vgg11", "mobilenet"].contains(&net)
            && pes > 0
            && sim_images > 0
            && profile_images > 0
            && alloc != "bogus";
        cimfab::prop_assert!(
            built.is_ok() == should_be_valid,
            "net={net:?} pes={pes} sim={sim_images} prof={profile_images} alloc={alloc}: \
             expected valid={should_be_valid}, got {built:?}"
        );
        Ok(())
    });
}

/// A deliberately silly strategy: every block gets exactly one copy
/// (ignores the extra budget). Registered at runtime to prove the API
/// is open end-to-end.
struct MinimalAllocator;

impl Allocator for MinimalAllocator {
    fn name(&self) -> &str {
        "minimal-test"
    }

    fn describe(&self) -> &str {
        "one copy of everything (test strategy)"
    }

    fn default_dataflow(&self) -> &str {
        "block-wise"
    }

    fn uniform_plans(&self) -> bool {
        false
    }

    fn allocate(
        &self,
        map: &NetworkMap,
        _profile: &NetworkProfile,
        budget_arrays: usize,
    ) -> cimfab::Result<AllocationPlan> {
        cimfab::alloc::finish_plan(AllocationPlan::minimal(map), self.name(), map, budget_arrays)
    }
}

#[test]
fn runtime_registered_strategy_drives_the_pipeline() {
    StrategyRegistry::register_global(Some(&MinimalAllocator), None).unwrap();
    let spec = PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed: 3,
        artifacts_dir: "artifacts".into(),
    };
    let sc = ScenarioBuilder::from_prefix(&spec)
        .alloc("minimal-test")
        .pes(172)
        .sim_images(4)
        .build()
        .unwrap();
    assert_eq!(sc.dataflow, "block-wise");
    let prep = pipeline::prepare(&spec, None).unwrap();
    let out = pipeline::run_scenario(&prep.view(), &sc, None).unwrap();
    assert_eq!(out.plan.algorithm, "minimal-test");
    assert_eq!(out.plan.arrays_used(&prep.map), prep.map.min_arrays());
    assert!(out.result.throughput_ips > 0.0);
    // a second registration under the same name is rejected
    assert!(StrategyRegistry::register_global(Some(&MinimalAllocator), None).is_err());
}

#[test]
fn paper_algorithms_resolve_by_name_with_expected_sim_config() {
    for name in PAPER_ALGORITHMS {
        let a = StrategyRegistry::lookup_allocator(name).unwrap();
        assert_eq!(a.name(), name);
        StrategyRegistry::lookup_dataflow(a.default_dataflow()).unwrap();
    }
    assert_eq!(
        StrategyRegistry::lookup_allocator("baseline").unwrap().read_mode(),
        cimfab::xbar::ReadMode::Baseline
    );
    assert_eq!(
        StrategyRegistry::lookup_allocator("block-wise").unwrap().default_dataflow(),
        "block-wise"
    );
}
