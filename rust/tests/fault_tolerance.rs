//! Permanent-fault suite: seeded fault maps, the fault-aware remap
//! pass, write-verify accounting, and graceful degradation, end to end
//! through the scenario pipeline.
//!
//! The contract under test:
//!
//! * fault-free runs are byte-identical to the pre-fault-model world —
//!   a zero-rate faulty run differs from a clean one only by the
//!   `faults` accounting object (and the scenario id);
//! * remapping onto spares measurably recovers the residual bit-error
//!   rate versus running the same damaged chip unrepaired;
//! * both simulation engines agree bit-for-bit on faulty runs;
//! * spare exhaustion is a clear diagnostic (naming `--spare-arrays`),
//!   never a panic, and `--no-fault-remap` still measures the chip;
//! * malformed fault-map files fail with errors carrying the path.

use cimfab::hw::FaultMap;
use cimfab::pipeline::{self, artifact, PrefixSpec, ScenarioBuilder, StatsSource};
use cimfab::util::json::Json;
use cimfab::util::propcheck;

/// CI pins `CIMFAB_TEST_SEED=7`; the fault axes reuse it so the sampled
/// fault maps are reproducible too.
fn test_seed() -> u64 {
    std::env::var("CIMFAB_TEST_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}

fn spec() -> PrefixSpec {
    PrefixSpec {
        net: "resnet18".into(),
        hw: 32,
        hw_profile: cimfab::hw::DEFAULT_PROFILE.into(),
        stats: StatsSource::Synthetic,
        profile_images: 1,
        seed: 7,
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn zero_rate_faults_keep_artifacts_byte_identical() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    propcheck::check("fault-free byte identity", 0xFA_10, 5, |rng| {
        let pes = 129 + rng.index(80);
        let base = ScenarioBuilder::from_prefix(&spec()).alloc("block-wise").pes(pes).sim_images(2);

        // clean run: no fault id segments, no faults key anywhere
        let off = base.clone().build().unwrap();
        cimfab::prop_assert!(!off.id().contains("_sa") && !off.id().contains("_flt"), "{}", off.id());
        let off_out = pipeline::run_scenario(&prep.view(), &off, None).unwrap();
        cimfab::prop_assert!(off_out.result.faults.is_none(), "clean runs must not report faults");
        let off_json = artifact::sim_result_json(&off_out.result).pretty();
        cimfab::prop_assert!(!off_json.contains("\"faults\""), "{off_json}");
        cimfab::prop_assert!(
            !off_out.report_json().pretty().contains("\"fault_"),
            "clean reports must not grow fault keys"
        );

        // zero-rate fault axes: the accounting object appears, all
        // zeros, and every other byte matches the clean artifact
        let zero = base
            .clone()
            .stuck_at_rate(0.0)
            .dead_array_rate(0.0)
            .fault_seed(test_seed())
            .build()
            .unwrap();
        cimfab::prop_assert!(zero.id().contains("_sa") && zero.id().contains("_flt"), "{}", zero.id());
        let zero_out = pipeline::run_scenario(&prep.view(), &zero, None).unwrap();
        let fl = zero_out.result.faults.expect("fault axes must always report FaultStats");
        cimfab::prop_assert!(
            fl.dead_arrays == 0
                && fl.retired_arrays == 0
                && fl.remapped_blocks == 0
                && fl.spares_used == 0
                && fl.derated_arrays == 0
                && fl.write_retries == 0
                && fl.residual_ber == 0.0,
            "zero rates must account nothing, got {fl:?}"
        );
        let mut stripped = artifact::sim_result_json(&zero_out.result);
        if let Json::Obj(m) = &mut stripped {
            m.remove("faults").expect("zero-rate artifact must carry the faults object");
        }
        cimfab::prop_assert!(
            stripped.pretty() == off_json,
            "zero-rate fault axes changed a fault-free artifact byte at pes={pes}"
        );
        Ok(())
    });
}

#[test]
fn remapping_onto_spares_recovers_residual_ber() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let faulty = |remap: bool| {
        let mut b = ScenarioBuilder::from_prefix(&spec())
            .alloc("block-wise")
            .pes(172)
            .sim_images(2)
            .stuck_at_rate(0.01)
            .dead_array_rate(0.01)
            .fault_seed(test_seed())
            .spare_arrays(256);
        if !remap {
            b = b.fault_remap(false);
        }
        pipeline::run_scenario(&prep.view(), &b.build().unwrap(), None).unwrap()
    };
    let with = faulty(true).result.faults.unwrap();
    let without = faulty(false).result.faults.unwrap();
    // the same sampled chip either way — only the repair differs
    assert_eq!(with.dead_arrays, without.dead_arrays);
    assert!(with.dead_arrays > 0, "{with:?}");
    assert!(with.remapped_blocks > 0 && with.spares_used > 0, "{with:?}");
    assert_eq!(without.remapped_blocks, 0, "{without:?}");
    assert_eq!(without.spares_used, 0, "{without:?}");
    assert!(
        with.residual_ber < without.residual_ber,
        "remapping must recover BER: {} (repaired) vs {} (as-is)",
        with.residual_ber,
        without.residual_ber
    );
}

#[test]
fn both_engines_agree_on_faulty_runs() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let base = ScenarioBuilder::from_prefix(&spec())
        .alloc("block-wise")
        .pes(172)
        .sim_images(2)
        .stuck_at_rate(0.01)
        .dead_array_rate(0.01)
        .fault_seed(test_seed())
        .spare_arrays(256);
    let ev = pipeline::run_scenario(&prep.view(), &base.clone().build().unwrap(), None).unwrap();
    let st = pipeline::run_scenario(&prep.view(), &base.engine("stepped").build().unwrap(), None)
        .unwrap();
    assert!(ev.result.faults.is_some());
    assert_eq!(ev.result.makespan, st.result.makespan);
    assert_eq!(
        artifact::sim_result_json(&ev.result).compact(),
        artifact::sim_result_json(&st.result).compact(),
        "engines diverged on a faulty chip"
    );
}

#[test]
fn spare_exhaustion_is_a_diagnostic_and_no_remap_still_measures() {
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let base = ScenarioBuilder::from_prefix(&spec())
        .alloc("block-wise")
        .pes(172)
        .sim_images(2)
        .dead_array_rate(0.01)
        .fault_seed(test_seed());
    // no reserve: repairing is impossible — a Result error naming the
    // knobs, not a panic
    let err = pipeline::run_scenario(&prep.view(), &base.clone().build().unwrap(), None)
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("exceed spare capacity"), "{msg}");
    assert!(msg.contains("--spare-arrays"), "{msg}");
    // the same chip runs unrepaired in degraded mode, damage accounted
    let out = pipeline::run_scenario(
        &prep.view(),
        &base.fault_remap(false).build().unwrap(),
        None,
    )
    .unwrap();
    let fl = out.result.faults.unwrap();
    assert!(fl.dead_arrays > 0 && fl.residual_ber > 0.0, "{fl:?}");
    assert_eq!(fl.spares_used, 0);
}

#[test]
fn malformed_fault_maps_fail_with_path_context() {
    let tmp = |case: &str, text: &str| {
        let p = std::env::temp_dir()
            .join(format!("cimfab-fault-map-{}-{case}.json", std::process::id()));
        std::fs::write(&p, text).unwrap();
        p.to_str().unwrap().to_string()
    };

    // the parser itself: precise per-field diagnostics
    for (text, needle) in [
        ("{not json", "invalid JSON"),
        (r#"{"arrays":4,"bogus":1}"#, "unknown fault-map field 'bogus'"),
        (r#"{"arrays":0}"#, "at least 1"),
        (r#"{"arrays":2,"dead":[5]}"#, "out of range"),
        (r#"{"arrays":2,"stuck":[{"array":0,"fraction":1.5}]}"#, "must be in [0, 1]"),
    ] {
        let err = FaultMap::from_json_text(text).unwrap_err();
        assert!(format!("{err:#}").contains(needle), "{text} -> {err:#}");
    }

    // load() wraps every failure with the offending path
    let missing = std::env::temp_dir().join("cimfab-no-such-fault-map.json");
    let _ = std::fs::remove_file(&missing);
    let err = FaultMap::load(missing.to_str().unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains(missing.to_str().unwrap()), "{err:#}");
    let garbage = tmp("garbage", "{not json");
    let err = FaultMap::load(&garbage).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&garbage) && msg.contains("invalid JSON"), "{msg}");

    // and the pipeline surfaces the same context from --fault-map
    let prep = pipeline::prepare(&spec(), None).unwrap();
    let sc = |path: &str| {
        ScenarioBuilder::from_prefix(&spec())
            .alloc("block-wise")
            .pes(172)
            .sim_images(2)
            .fault_map(path)
            .build()
            .unwrap()
    };
    let err = pipeline::run_scenario(&prep.view(), &sc(&garbage), None).map(|_| ()).unwrap_err();
    assert!(format!("{err:#}").contains(&garbage), "{err:#}");
    let undersized = tmp("undersized", r#"{"arrays":4}"#);
    let err =
        pipeline::run_scenario(&prep.view(), &sc(&undersized), None).map(|_| ()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("covers 4 arrays"), "{msg}");
    for p in [garbage, undersized] {
        let _ = std::fs::remove_file(p);
    }
}
