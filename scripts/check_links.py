#!/usr/bin/env python3
"""Markdown link checker for the docs site and the root markdown.

Checks every markdown file passed on the command line (or, with no
arguments, every *.md at the repo root — README, ROADMAP, CHANGES, … —
plus docs/**/*.md) for:

  * relative links whose target file does not exist;
  * intra-document anchor links (#heading) with no matching heading.

External (http/https/mailto) links are not fetched — CI must stay
offline-deterministic. Exits non-zero listing every broken link.
"""

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dash spaces."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\s-]", "", text, flags=re.UNICODE)
    return re.sub(r"\s+", "-", text)


def check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as fh:
        raw = fh.read()
    text = CODE_FENCE_RE.sub("", raw)  # links inside code fences are examples
    anchors = {slugify(h) for h in HEADING_RE.findall(text)}
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        if file_part:
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), file_part)
            )
            if not os.path.exists(resolved):
                errors.append(f"{path}: broken link -> {target}")
                continue
            if anchor and resolved.endswith(".md"):
                with open(resolved, encoding="utf-8") as fh:
                    t_anchors = {
                        slugify(h)
                        for h in HEADING_RE.findall(CODE_FENCE_RE.sub("", fh.read()))
                    }
                if anchor not in t_anchors:
                    errors.append(f"{path}: broken anchor -> {target}")
        elif anchor and anchor not in anchors:
            errors.append(f"{path}: broken anchor -> #{anchor}")
    return errors


def main() -> int:
    files = sys.argv[1:]
    if not files:
        # every root-level markdown file (historically only README.md,
        # which silently skipped ROADMAP.md and friends) …
        files = sorted(n for n in os.listdir(".") if n.endswith(".md"))
        # … plus the docs tree
        for root, _, names in os.walk("docs"):
            files += [os.path.join(root, n) for n in names if n.endswith(".md")]
    all_errors = []
    for f in files:
        all_errors += check_file(f)
    for e in all_errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files, {len(all_errors)} broken links")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
