#!/usr/bin/env python3
"""Minimal client for the `cimfab serve` JSON-lines wire protocol.

Talks to a daemon over a Unix socket (--socket) or TCP (--connect),
sends one request, and prints every response line until the exchange is
complete. Used by the CI serve smoke test; stdlib only.

Examples:
    cimfab serve --socket /tmp/cimfab.sock &
    scripts/serve_client.py --socket /tmp/cimfab.sock --wait-listening 10 \
        submit --net resnet18 --res 32 --alloc block-wise --pes 129 --images 2
    scripts/serve_client.py --socket /tmp/cimfab.sock stats
    scripts/serve_client.py --socket /tmp/cimfab.sock cancel --job job-1
    scripts/serve_client.py --socket /tmp/cimfab.sock shutdown
"""

import argparse
import json
import socket
import sys
import time


def connect(args):
    deadline = time.monotonic() + args.wait_listening
    while True:
        try:
            if args.socket:
                s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                s.connect(args.socket)
            else:
                host, _, port = args.connect.rpartition(":")
                s = socket.create_connection((host, int(port)))
            return s
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.1)


def build_request(args):
    if args.op == "submit":
        scenario = {"alloc": args.alloc, "pes": args.pes, "images": args.images}
        if args.dataflow:
            scenario["dataflow"] = args.dataflow
        if args.engine:
            scenario["engine"] = args.engine
        req = {
            "op": "submit",
            "net": args.net,
            "res": args.res,
            "seed": args.seed,
            "scenarios": [scenario],
        }
        if args.id:
            req["id"] = args.id
        if args.priority:
            req["priority"] = args.priority
        return req
    if args.op == "cancel":
        return {"op": "cancel", "job": args.job}
    return {"op": args.op}


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--socket", help="Unix socket path of the daemon")
    p.add_argument("--connect", help="TCP address host:port of the daemon")
    # takes an explicit value: with nargs='?' argparse would swallow the
    # following subcommand token ("submit") as the float and exit 2
    p.add_argument(
        "--wait-listening",
        type=float,
        default=0.0,
        metavar="SECS",
        help="retry connecting for up to SECS seconds (default: no retry)",
    )
    sub = p.add_subparsers(dest="op", required=True)

    submit = sub.add_parser("submit", help="submit a one-scenario job")
    submit.add_argument("--id", help="client-chosen job id")
    submit.add_argument("--priority", type=int, default=0)
    submit.add_argument("--net", default="resnet18")
    submit.add_argument("--res", type=int, default=64)
    submit.add_argument("--seed", type=int, default=7)
    submit.add_argument("--alloc", default="block-wise")
    submit.add_argument("--dataflow")
    submit.add_argument("--engine")
    submit.add_argument("--pes", type=int, required=True)
    submit.add_argument("--images", type=int, default=8)

    cancel = sub.add_parser("cancel", help="cancel a live job by id")
    cancel.add_argument("--job", required=True)
    sub.add_parser("stats", help="print server + telemetry counters")
    sub.add_parser("shutdown", help="drain and stop the daemon")

    args = p.parse_args()
    if bool(args.socket) == bool(args.connect):
        p.error("need exactly one of --socket or --connect")

    req = build_request(args)
    with connect(args) as s:
        s.sendall((json.dumps(req) + "\n").encode())
        # read until the exchange's terminal line; submit streams result
        # lines and ends with this job's "done"
        reader = s.makefile("r", encoding="utf-8")
        accepted = False
        for line in reader:
            line = line.strip()
            if not line:
                continue
            reply = json.loads(line)  # malformed output should fail the smoke
            print(line)
            kind = reply.get("type")
            if args.op == "submit":
                if kind == "accepted":
                    accepted = True
                elif kind == "error" and not accepted:
                    sys.exit(1)  # rejected before admission: no done follows
                elif kind == "done":
                    sys.exit(0 if reply.get("ok", 0) > 0 and not reply.get("failed") else 1)
            elif kind == "error":
                sys.exit(1)
            elif kind in ("stats", "cancelled", "shutting_down"):
                sys.exit(0)
        sys.exit(1)  # connection closed before a terminal line


if __name__ == "__main__":
    main()
