"""Roofline estimator sanity: VMEM headroom + structural MXU ceiling."""

from compile.kernels import roofline


def test_default_tile_fits_vmem_with_huge_headroom():
    e = roofline.estimate()
    assert e.fits_vmem()
    assert e.vmem_fraction < 0.02, f"default tile uses {e.vmem_fraction:.2%} of VMEM"


def test_tile_can_grow_64x_before_pressure():
    e = roofline.estimate(tile_p=16 * 64)
    assert e.fits_vmem(), f"1024-patch tile should still fit ({e.vmem_bytes} B)"


def test_mxu_ceiling_matches_adc_structure():
    e = roofline.estimate()
    # 8-row groups on a 128-deep MXU, 16 columns on 128 lanes
    assert abs(e.mxu_ceiling - (8 / 128) * (16 / 128)) < 1e-12


def test_wider_adc_raises_ceiling():
    lo = roofline.estimate(adc_bits=3)
    hi = roofline.estimate(adc_bits=5)
    assert hi.mxu_ceiling > lo.mxu_ceiling


def test_vmem_scales_linearly_in_tile():
    a = roofline.estimate(tile_p=16)
    b = roofline.estimate(tile_p=32)
    # w_tile is tile-independent; the rest doubles
    assert a.vmem_bytes < b.vmem_bytes < 2 * a.vmem_bytes


def test_report_renders():
    r = roofline.report()
    assert "VMEM" in r and "MXU" in r
