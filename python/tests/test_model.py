"""L2 model correctness: shapes, quantization, kernel-vs-model identity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import cim_matmul as K
from compile.kernels import ref


@pytest.fixture(scope="module")
def rn18():
    return M.build_resnet18(hw=32, num_classes=10, seed=0)


@pytest.fixture(scope="module")
def rn18_out(rn18):
    img = M.synthetic_image(32, seed=3)
    return rn18.apply(jnp.asarray(img))


def test_resnet18_has_20_convs(rn18):
    assert len(rn18.specs) == 20  # paper: 20 convolutional layers
    names = [s.name for s in rn18.specs]
    # projection shortcuts appear after their block's conv2 (rust order)
    assert names.index("l2b0.downsample") == names.index("l2b0.conv2") + 1


def test_activation_shapes_match_specs(rn18, rn18_out):
    acts, logits = rn18_out
    assert len(acts) == 20
    for a, s in zip(acts, rn18.specs):
        assert a.dtype == jnp.uint8
        assert a.shape[0] == s.in_ch, f"{s.name}: {a.shape}"
    assert logits.shape == (10,)


def test_stem_sees_dense_pixels_deeper_layers_sparser(rn18_out):
    acts, _ = rn18_out
    def density(a):
        bits = np.unpackbits(np.asarray(a).reshape(-1))
        return bits.mean()
    d0 = density(acts[0])
    deep = [density(a) for a in acts[5:]]
    assert d0 > 0.3, f"stem density {d0} should be pixel-like"
    assert np.mean(deep) < d0, "post-ReLU layers should be sparser than pixels"


def test_vgg11_shapes():
    m = M.build_vgg11(hw=32, num_classes=10, seed=1)
    acts, logits = m.apply(jnp.asarray(M.synthetic_image(32, seed=4)))
    assert len(acts) == 8
    assert acts[0].shape == (3, 32, 32)
    assert acts[-1].shape == (512, 2, 2)  # after 4 of the 5 pools: 32→2
    assert logits.shape == (10,)


def test_forward_flat_equals_apply(rn18):
    img = jnp.asarray(M.synthetic_image(32, seed=5))
    a1, l1 = rn18.apply(img)
    a2, l2 = rn18.forward_flat(img, jnp.asarray(rn18.flat_weights()))
    for x, y in zip(a1, a2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)


def test_weight_layout_covers_buffer(rn18):
    layout = rn18.weight_layout()
    flat = rn18.flat_weights()
    total = sum(int(np.prod(e["shape"])) for e in layout)
    assert total == flat.size
    # offsets strictly increasing and contiguous
    off = 0
    for e in layout:
        assert e["offset"] == off
        off += int(np.prod(e["shape"]))


def test_model_conv_matches_pallas_kernel(rn18, rn18_out):
    """The L2 integer conv and the L1 crossbar kernel compute the same
    numbers: take a real layer's quantized input, run its first 128-row
    slice / 16-column tile through the Pallas kernel, compare with the
    plain integer matmul the model used."""
    acts, _ = rn18_out
    i = next(j for j, s in enumerate(rn18.specs) if s.name == "l1b0.conv1")
    spec = rn18.specs[i]
    x_q = np.asarray(acts[i])
    patches, _, _ = M.im2col(jnp.asarray(x_q.astype(np.int32)), spec.k, spec.stride, spec.pad)
    patches = np.asarray(patches).astype(np.uint8)  # values ≤ 255
    # one sub-array worth: first 128 rows x first 16 weight columns
    xs = patches[:32, :128]
    ws = rn18.conv_w[i][:128, :16]
    got = K.cim_matmul(xs, ws, adc_bits=3)
    want = np.asarray(ref.matmul_exact(xs, ws))
    np.testing.assert_array_equal(got, want)


def test_im2col_matches_rust_contract():
    # channel-major, then ky, kx — pinned against a hand example mirroring
    # rust tensor::im2col tests.
    x = jnp.arange(8, dtype=jnp.int32).reshape(2, 2, 2)  # [C=2, 2, 2]
    patches, oh, ow = M.im2col(x, k=2, stride=1, pad=0)
    assert (oh, ow) == (1, 1)
    np.testing.assert_array_equal(np.asarray(patches)[0], [0, 1, 2, 3, 4, 5, 6, 7])


def test_quantize_act_range():
    x = jnp.asarray([0.0, 1.0, 2.0])
    q, scale = M.quantize_act(x)
    assert q.dtype == jnp.uint8
    assert int(q[2]) == 255
    assert float(scale) == pytest.approx(2.0 / 255.0)


def test_deterministic_weights():
    a = M.build_resnet18(32, 10, seed=7).flat_weights()
    b = M.build_resnet18(32, 10, seed=7).flat_weights()
    c = M.build_resnet18(32, 10, seed=8).flat_weights()
    assert (a == b).all()
    assert (a != c).any()
