"""AOT export round-trip: HLO text parses and manifest is consistent.

The definitive cross-language check (execute-from-Rust) lives in
``rust/tests/integration_runtime.rs``; here we validate the python side:
the text re-parses into an XlaComputation and executes on the local CPU
client with the same numbers as the jit path.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def out_dir():
    with tempfile.TemporaryDirectory() as d:
        yield d


def test_cim_kernel_hlo_roundtrip(out_dir):
    meta = aot.export_cim_kernel(out_dir, patches=16, rows=16, cols=4)
    text = open(os.path.join(out_dir, meta["hlo"])).read()
    assert "ENTRY" in text
    # re-parse through the HLO text parser (what the rust side does)
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_model_export_and_manifest(out_dir):
    meta = aot.export_model("vgg11", 32, seed=1, out_dir=out_dir)
    assert meta["weight_bytes"] > 0
    wpath = os.path.join(out_dir, meta["weights"])
    assert os.path.getsize(wpath) == meta["weight_bytes"]
    assert len(meta["conv_layers"]) == 8
    assert meta["outputs"][-1] == "logits"
    text = open(os.path.join(out_dir, meta["hlo"])).read()
    assert "ENTRY" in text
    # weights as a parameter, not constants: the HLO must stay small
    assert os.path.getsize(os.path.join(out_dir, meta["hlo"])) < 2_000_000


def test_exported_model_executes_with_same_numbers(out_dir):
    """Compile the exported HLO text with the local PJRT CPU client and
    compare against the jit path — same as the Rust runtime will do."""
    meta = aot.export_model("vgg11", 32, seed=1, out_dir=out_dir)
    text = open(os.path.join(out_dir, meta["hlo"])).read()

    qm = M.build("vgg11", 32, seed=1)
    img = M.synthetic_image(32, seed=2)
    wflat = np.fromfile(os.path.join(out_dir, meta["weights"]), dtype=np.int8)

    acts_ref, logits_ref = jax.jit(qm.forward_flat)(jnp.asarray(img), jnp.asarray(wflat))

    client = xc._xla.get_local_backend("cpu") if hasattr(xc._xla, "get_local_backend") else None
    if client is None:
        client = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text)
    # Execute through jax's CPU client via the XlaComputation API if
    # available; otherwise, at minimum the parse above validates the text.
    try:
        executable = client.compile(xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()))
    except Exception:
        pytest.skip("local client cannot compile raw HLO (rust side covers this)")
    outs = executable.execute([client.buffer_from_pyval(img), client.buffer_from_pyval(wflat)])
    flat = outs[0] if isinstance(outs[0], (list, tuple)) else outs
    got_logits = np.asarray(flat[-1])
    np.testing.assert_allclose(got_logits, np.asarray(logits_ref), rtol=1e-5, atol=1e-5)


def test_main_manifest_schema(tmp_path):
    # run the full exporter on a throwaway dir with one tiny net
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--hw", "32", "--nets", "vgg11"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["schema"] == aot.SCHEMA_VERSION
    assert "vgg11" in manifest["models"]
    assert "cim_matmul" in manifest["kernels"]
    for f in [manifest["models"]["vgg11"]["hlo"], manifest["kernels"]["cim_matmul"]["hlo"]]:
        assert (tmp_path / f).exists()
