"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps).

The chain under test, end to end:
    Pallas `cim_matmul`  ==  ref.adc_model  ==  ref.matmul_exact
(the right identity holding whenever group_rows == 2**adc_bits), plus the
`bitstats` profiling kernel against its reference. The same semantics are
implemented in Rust (`xbar::SubArray`, `util::bitops`) and pinned there
by mirrored unit tests.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cim_matmul as k
from compile.kernels import ref


def rand_case(seed, p, r, c):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=(p, r), dtype=np.uint8)
    w = rng.integers(-128, 128, size=(r, c), dtype=np.int8)
    return x, w


# --- exactness of the paper's operating point ------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    p=st.integers(1, 40),
    r=st.sampled_from([8, 16, 24, 64, 120, 128]),
    c=st.integers(1, 16),
)
def test_pallas_matches_exact_matmul(seed, p, r, c):
    x, w = rand_case(seed, p, r, c)
    got = k.cim_matmul(x, w, adc_bits=3)
    want = np.asarray(ref.matmul_exact(x, w))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    adc_bits=st.sampled_from([1, 2, 3, 4]),
)
def test_lossless_for_any_adc_when_batched_to_match(seed, adc_bits):
    # group_rows == 2**adc_bits ⇒ the ADC never saturates (paper §II).
    x, w = rand_case(seed, 8, 64, 4)
    got = k.cim_matmul(x, w, adc_bits=adc_bits)
    want = np.asarray(ref.matmul_exact(x, w))
    np.testing.assert_array_equal(got, want)


# --- saturation of under-provisioned ADCs (§III-A) -------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_oversized_batches_match_adc_model(seed):
    # 32-row batches on a 3-bit ADC: the prior-work regime. The kernel
    # must agree with the saturating reference, not the exact product.
    x, w = rand_case(seed, 8, 64, 4)
    got = k.cim_matmul(x, w, adc_bits=3, group_rows=32)
    want = np.asarray(ref.adc_model(x, w, adc_bits=3, group_rows=32))
    np.testing.assert_array_equal(got, want)


def test_oversized_batches_actually_err():
    # all-ones inputs and positive weights force saturation
    x = np.full((4, 64), 255, dtype=np.uint8)
    w = np.ones((64, 4), dtype=np.int8)
    exact = np.asarray(ref.matmul_exact(x, w))
    clipped = k.cim_matmul(x, w, adc_bits=3, group_rows=64)
    assert (clipped < exact).all(), "64-row reads on a 3-bit ADC must clip"


# --- structured edge cases --------------------------------------------------


def test_zero_input_gives_zero():
    x = np.zeros((4, 128), dtype=np.uint8)
    w = np.full((128, 16), 55, dtype=np.int8)
    np.testing.assert_array_equal(k.cim_matmul(x, w), 0)


def test_negative_weights_recombine():
    x = np.zeros((1, 8), dtype=np.uint8)
    x[0, 0] = 255
    w = np.zeros((8, 2), dtype=np.int8)
    w[0, 0] = -128
    w[0, 1] = -1
    out = k.cim_matmul(x, w)
    assert out[0, 0] == -128 * 255
    assert out[0, 1] == -255

def test_single_patch_and_column():
    x, w = rand_case(7, 1, 8, 1)
    got = k.cim_matmul(x, w)
    np.testing.assert_array_equal(got, np.asarray(ref.matmul_exact(x, w)))


def test_unpadded_row_counts_rejected_via_padding():
    # R not a multiple of the group: wrapper pads with zero rows, which
    # must not change the result.
    x, w = rand_case(11, 5, 8, 3)
    x3, w3 = x[:, :6].copy(), w[:6].copy()
    got = k.cim_matmul(x3, w3)
    want = np.asarray(ref.matmul_exact(x3, w3))
    np.testing.assert_array_equal(got, want)


# --- bitstats ----------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    p=st.integers(1, 50),
    r=st.integers(1, 128),
)
def test_bitstats_matches_reference(seed, p, r):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=(p, r), dtype=np.uint8)
    got = k.bitstats(x)
    want = np.asarray(ref.plane_counts(x))
    np.testing.assert_array_equal(got, want)


def test_zs_cycles_paper_extremes():
    # full-on 128-row slice: 16 batches × 8 planes × 8 mux = 1024
    counts = np.full((1, 8), 128, dtype=np.int32)
    assert int(ref.zs_cycles(counts)[0]) == 1024
    # ≤8 ones per plane: 8 batches total × 8 mux = 64
    counts = np.full((1, 8), 8, dtype=np.int32)
    assert int(ref.zs_cycles(counts)[0]) == 64
    # all-zero: free
    counts = np.zeros((1, 8), dtype=np.int32)
    assert int(ref.zs_cycles(counts)[0]) == 0
