"""Pure-jnp oracles for the L1 CIM kernels.

These are the correctness references the Pallas kernels (and, across the
language boundary, the Rust ``xbar::SubArray`` model) must match exactly:

* :func:`matmul_exact` — the ideal integer dot product a crossbar layer
  computes when the ADC discipline never saturates.
* :func:`adc_model` — the bit-serial, row-batched, ADC-clipped procedure
  of the hardware (paper Fig 1(B)): 8-bit signed weights as binary cell
  planes (two's complement, MSB negative), unsigned 8-bit inputs shifted
  in LSB-first, each input bit-plane read in ``group_rows``-row batches
  whose analog sum is digitized by a ``adc_bits``-bit ADC (saturating at
  ``2**adc_bits``), then shift-and-add recombination.

With ``group_rows == 2**adc_bits`` (the paper's discipline: 8 rows on a
3-bit ADC) the clip never binds and ``adc_model == matmul_exact`` — that
identity is what lets the whole performance simulator use exact integer
math. With larger batches (prior work's 5–8-bit ADCs over 128 rows) the
model exhibits exactly the saturation errors §III-A warns about.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

INPUT_BITS = 8
WEIGHT_BITS = 8


def weight_planes(w: np.ndarray) -> np.ndarray:
    """Decompose signed int8 weights ``[R, C]`` into binary cell planes
    ``[WEIGHT_BITS, R, C]`` (two's complement bit patterns)."""
    assert w.dtype == np.int8
    u = w.astype(np.uint8)
    return np.stack([((u >> b) & 1).astype(np.int32) for b in range(WEIGHT_BITS)])


def plane_significance() -> np.ndarray:
    """Per-weight-plane significance: [1, 2, …, 64, -128]."""
    sig = [1 << b for b in range(WEIGHT_BITS - 1)] + [-(1 << (WEIGHT_BITS - 1))]
    return np.asarray(sig, dtype=np.int32)


def matmul_exact(x: np.ndarray, w: np.ndarray) -> jnp.ndarray:
    """Ideal integer product: ``x (u8 [P, R]) @ w (i8 [R, C]) -> i32``."""
    assert x.dtype == np.uint8 and w.dtype == np.int8
    return jnp.dot(x.astype(np.int32), w.astype(np.int32))


def adc_model(
    x: np.ndarray,
    w: np.ndarray,
    *,
    adc_bits: int = 3,
    group_rows: int | None = None,
) -> jnp.ndarray:
    """Bit-serial ADC-batched product (see module docstring).

    ``group_rows`` defaults to ``2**adc_bits`` (the paper's lossless
    discipline). R must be padded to a multiple of ``group_rows`` by the
    caller (zero rows are harmless).
    """
    assert x.dtype == np.uint8 and w.dtype == np.int8
    if group_rows is None:
        group_rows = 1 << adc_bits
    p, r = x.shape
    r2, c = w.shape
    assert r == r2, f"shape mismatch {x.shape} vs {w.shape}"
    assert r % group_rows == 0, f"R={r} not a multiple of group_rows={group_rows}"
    g = r // group_rows
    adc_max = 1 << adc_bits

    planes = jnp.asarray(weight_planes(w)).reshape(WEIGHT_BITS, g, group_rows, c)
    sig = jnp.asarray(plane_significance())
    xi = jnp.asarray(x.astype(np.int32)).reshape(p, g, group_rows)

    acc = jnp.zeros((p, c), jnp.int32)
    for ib in range(INPUT_BITS):
        xb = (xi >> ib) & 1  # [P, G, group_rows]
        # one ADC sample per (weight plane, patch, group, column)
        s = jnp.einsum("pgr,wgrc->wpgc", xb, planes)
        code = jnp.clip(s, 0, adc_max)
        contrib = jnp.einsum("wpgc,w->pc", code, sig)
        acc = acc + (contrib << ib)
    return acc


def plane_counts(x: np.ndarray) -> jnp.ndarray:
    """Per-input-bit-plane ones count: ``u8 [P, R] -> i32 [P, INPUT_BITS]``.

    Reference for the `bitstats` profiling kernel; mirrors Rust
    ``util::bitops::plane_counts``.
    """
    assert x.dtype == np.uint8
    xi = jnp.asarray(x.astype(np.int32))
    return jnp.stack([jnp.sum((xi >> b) & 1, axis=1) for b in range(INPUT_BITS)], axis=1)


def zs_cycles(counts: jnp.ndarray, *, adc_bits: int = 3, col_mux: int = 8) -> jnp.ndarray:
    """Zero-skip cycle cost from plane counts (mirrors Rust
    ``xbar::scheduler::zs_cycles``): ``Σ_b ceil(ones_b / 2^adc) × mux``."""
    adc_rows = 1 << adc_bits
    batches = -(-counts // adc_rows)  # ceil div, 0 stays 0
    return jnp.sum(batches, axis=1) * col_mux
