"""L1 Pallas kernels: the CIM crossbar compute hot-spot.

Two kernels, both tiled over patches with a ``BlockSpec`` so one grid
step holds a single (patch-tile × 128-row × 16-column) working set in
VMEM — the same HBM↔VMEM schedule the accelerator's input buffers
implement (DESIGN.md §Hardware-Adaptation):

* :func:`cim_matmul` — the bit-serial, ADC-batched matrix product of one
  crossbar sub-array (the functional twin of Rust
  ``xbar::SubArray::matvec`` and of ``ref.adc_model``).
* :func:`bitstats` — per-input-bit-plane ones counts (the profiling
  hot-spot behind the paper's Figs 4 & 6; functional twin of Rust
  ``util::bitops::plane_counts``).

Pallas runs with ``interpret=True`` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so the kernels lower to plain HLO
(see /opt/xla-example/README.md). VMEM/MXU estimates for a real TPU are
recorded in DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

INPUT_BITS = ref.INPUT_BITS
WEIGHT_BITS = ref.WEIGHT_BITS

# Patch-tile height: one grid step processes this many patches.
TILE_P = 16


def _cim_kernel(x_ref, w_ref, o_ref, *, adc_bits: int, group_rows: int):
    """One grid step: ``x [TP, R] i32`` × planes ``w [WB, R, C] i32``."""
    x = x_ref[...]
    w = w_ref[...]
    tp, r = x.shape
    wb, _, c = w.shape
    g = r // group_rows
    adc_max = 1 << adc_bits

    xg = x.reshape(tp, g, group_rows)
    wg = w.reshape(wb, g, group_rows, c)
    # Per-plane significance as Python ints (pallas kernels may not
    # capture constant arrays): [1, 2, …, 64, -128].
    sig = [int(s) for s in ref.plane_significance()]

    acc = jnp.zeros((tp, c), jnp.int32)
    for ib in range(INPUT_BITS):
        xb = (xg >> ib) & 1
        # ADC samples: one per (weight plane, patch, row group, column).
        s = jnp.einsum("pgr,wgrc->wpgc", xb, wg, preferred_element_type=jnp.int32)
        code = jnp.clip(s, 0, adc_max)  # the ADC transfer function
        # shift-and-add recombination across weight planes
        contrib = sum(sig[b] * jnp.sum(code[b], axis=1) for b in range(wb))
        acc = acc + (contrib << ib)
    o_ref[...] = acc


def _pad_to(a: np.ndarray, axis: int, multiple: int) -> np.ndarray:
    n = a.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return np.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("adc_bits", "group_rows"))
def _cim_call(x_i32, planes_i32, *, adc_bits: int, group_rows: int):
    p, r = x_i32.shape
    c = planes_i32.shape[2]
    grid = (p // TILE_P,)
    return pl.pallas_call(
        functools.partial(_cim_kernel, adc_bits=adc_bits, group_rows=group_rows),
        out_shape=jax.ShapeDtypeStruct((p, c), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_P, r), lambda i: (i, 0)),
            pl.BlockSpec((WEIGHT_BITS, r, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_P, c), lambda i: (i, 0)),
        interpret=True,
    )(x_i32, planes_i32)


def cim_matmul(
    x: np.ndarray,
    w: np.ndarray,
    *,
    adc_bits: int = 3,
    group_rows: int | None = None,
) -> np.ndarray:
    """Crossbar product ``x (u8 [P, R]) × w (i8 [R, C]) -> i32 [P, C]``.

    ``group_rows`` defaults to ``2**adc_bits`` (the paper's lossless
    discipline); pass more rows to reproduce the §III-A saturation of
    under-provisioned ADCs.
    """
    assert x.dtype == np.uint8 and w.dtype == np.int8
    if group_rows is None:
        group_rows = 1 << adc_bits
    p, r = x.shape
    planes = ref.weight_planes(w)  # [WB, R, C]
    xp = _pad_to(_pad_to(x.astype(np.int32), 1, group_rows), 0, TILE_P)
    wp = _pad_to(planes, 1, group_rows)
    out = _cim_call(
        jnp.asarray(xp), jnp.asarray(wp), adc_bits=adc_bits, group_rows=group_rows
    )
    return np.asarray(out)[:p]


def _bitstats_kernel(x_ref, o_ref):
    x = x_ref[...]
    cols = [jnp.sum((x >> b) & 1, axis=1) for b in range(INPUT_BITS)]
    o_ref[...] = jnp.stack(cols, axis=1)


@jax.jit
def _bitstats_call(x_i32):
    p, r = x_i32.shape
    return pl.pallas_call(
        _bitstats_kernel,
        out_shape=jax.ShapeDtypeStruct((p, INPUT_BITS), jnp.int32),
        grid=(p // TILE_P,),
        in_specs=[pl.BlockSpec((TILE_P, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_P, INPUT_BITS), lambda i: (i, 0)),
        interpret=True,
    )(x_i32)


def bitstats(x: np.ndarray) -> np.ndarray:
    """Per-bit-plane ones counts: ``u8 [P, R] -> i32 [P, 8]``."""
    assert x.dtype == np.uint8
    p = x.shape[0]
    xp = _pad_to(x.astype(np.int32), 0, TILE_P)
    return np.asarray(_bitstats_call(jnp.asarray(xp)))[:p]


# ---------------------------------------------------------------------------
# jit-able graph fragments for AOT export (called from compile.aot): same
# kernels but taking jnp arrays so they lower into the surrounding HLO.
# ---------------------------------------------------------------------------


def cim_matmul_graph(x_i32, planes_i32, *, adc_bits: int = 3):
    """Traceable kernel call for AOT export; shapes must be pre-padded
    (P multiple of TILE_P, R multiple of ``2**adc_bits``)."""
    return _cim_call(x_i32, planes_i32, adc_bits=adc_bits, group_rows=1 << adc_bits)


def bitstats_graph(x_i32):
    """Traceable bitstats call for AOT export (P multiple of TILE_P)."""
    return _bitstats_call(x_i32)
