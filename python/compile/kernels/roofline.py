"""L1 TPU roofline estimator (structural, DESIGN.md §9).

Pallas runs under ``interpret=True`` here (CPU PJRT cannot execute
Mosaic custom-calls), so TPU performance statements are *estimates from
the kernel's BlockSpec structure*, not wallclock: VMEM footprint of one
grid step, and the MXU utilization ceiling imposed by the ADC-batched
compute pattern.

The interesting (and honest) result: modeling the crossbar faithfully
costs MXU efficiency by construction. The ADC clips after every
``group_rows``-row partial sum, so the longest uninterrupted contraction
the MXU can run is ``group_rows`` (8) instead of the full 128 rows — a
~`group_rows / 128` structural ceiling before int8-packing games. This
is a property of simulating the hardware, not a missed optimization;
EXPERIMENTS.md §Perf quotes these numbers.
"""

from __future__ import annotations

import dataclasses

from . import cim_matmul as K

# TPU v4-ish per-core budgets (order-of-magnitude constants for the
# estimate; exact values vary by generation).
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    tile_p: int
    rows: int
    cols: int
    group_rows: int
    vmem_bytes: int
    vmem_fraction: float
    #: MACs actually needed per grid step (useful work)
    macs: int
    #: MXU utilization ceiling from the batched-contraction structure
    mxu_ceiling: float

    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES


def estimate(
    tile_p: int = K.TILE_P,
    rows: int = 128,
    cols: int = 16,
    *,
    adc_bits: int = 3,
    input_bits: int = K.INPUT_BITS,
    weight_bits: int = K.WEIGHT_BITS,
) -> KernelEstimate:
    """Estimate one grid step of `cim_matmul` (all buffers i32)."""
    group_rows = 1 << adc_bits
    groups = -(-rows // group_rows)
    i32 = 4
    x_tile = tile_p * rows * i32
    w_tile = weight_bits * rows * cols * i32
    out_tile = tile_p * cols * i32
    # largest intermediate: the per-(plane, patch, group, col) ADC codes
    codes = weight_bits * tile_p * groups * cols * i32
    vmem = x_tile + w_tile + out_tile + codes

    macs = tile_p * rows * cols * input_bits * weight_bits  # bit-plane MACs
    # Structure: per (input bit, weight plane) the kernel runs `groups`
    # independent (tile_p × group_rows) × (group_rows × cols)
    # contractions. The MXU's 128-deep systolic contraction is cut to
    # group_rows, and cols < 128 leaves lanes idle:
    mxu_ceiling = min(1.0, group_rows / MXU_DIM) * min(1.0, cols / MXU_DIM)

    return KernelEstimate(
        tile_p=tile_p,
        rows=rows,
        cols=cols,
        group_rows=group_rows,
        vmem_bytes=vmem,
        vmem_fraction=vmem / VMEM_BYTES,
        macs=macs,
        mxu_ceiling=mxu_ceiling,
    )


def report() -> str:
    lines = ["cim_matmul TPU roofline estimate (structural)"]
    for tile_p in (16, 128, 1024):
        e = estimate(tile_p=tile_p)
        lines.append(
            f"  tile_p={tile_p:5d}: VMEM {e.vmem_bytes / 1024:8.1f} KiB "
            f"({e.vmem_fraction * 100:5.2f}% of 16 MiB), "
            f"MXU ceiling {e.mxu_ceiling * 100:4.1f}%"
        )
    e = estimate()
    lines.append(
        f"  structural MXU ceiling = (group {e.group_rows}/{MXU_DIM}) x "
        f"(cols {e.cols}/{MXU_DIM}) — set by the ADC batching the kernel models"
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
