"""AOT export: lower the L2 models + L1 kernels to HLO *text*.

Run once at build time (``make artifacts``); Python never executes on
the request path. The Rust runtime (``rust/src/runtime``) loads each
``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file``,
compiles it on the PJRT CPU client, and executes it.

HLO **text** — not ``.serialize()`` — is the interchange format: jax ≥
0.5 emits HloModuleProtos with 64-bit instruction ids that the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Exported artifacts:

* ``<net>_stats.hlo.txt``   — fn(image f32[3,hw,hw], wflat i8[N]) →
  (u8 input activations of every conv layer…, f32 logits). Drives the
  Rust profiling + golden paths.
* ``weights_<net>.bin``     — the flat i8 weight buffer for that model.
* ``cim_matmul.hlo.txt``    — the Pallas crossbar kernel (one 128×16
  sub-array, 16-patch tile), interpret-lowered.
* ``bitstats.hlo.txt``      — the Pallas profiling kernel.
* ``manifest.json``         — shapes, dtypes, seeds, weight layout.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import cim_matmul as K

SCHEMA_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(net: str, hw: int, seed: int, out_dir: str) -> dict:
    qm = M.build(net, hw, seed=seed)
    wflat = qm.flat_weights()
    img_spec = jax.ShapeDtypeStruct((3, hw, hw), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((wflat.size,), jnp.int8)

    def fn(image, wflat_param):
        acts, logits = qm.forward_flat(image, wflat_param)
        return (*acts, logits)

    lowered = jax.jit(fn).lower(img_spec, w_spec)
    hlo_path = os.path.join(out_dir, f"{net}_stats.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(to_hlo_text(lowered))

    wpath = os.path.join(out_dir, f"weights_{net}.bin")
    wflat.tofile(wpath)

    return {
        "hlo": os.path.basename(hlo_path),
        "weights": os.path.basename(wpath),
        "weight_bytes": int(wflat.size),
        "hw": hw,
        "seed": seed,
        "num_classes": qm.num_classes,
        "conv_layers": [
            {
                "name": s.name,
                "in_ch": s.in_ch,
                "out_ch": s.out_ch,
                "k": s.k,
                "stride": s.stride,
                "pad": s.pad,
            }
            for s in qm.specs
        ],
        "weight_layout": qm.weight_layout(),
        "outputs": [f"act:{s.name}" for s in qm.specs] + ["logits"],
    }


def export_cim_kernel(out_dir: str, patches: int = 16, rows: int = 128, cols: int = 16) -> dict:
    x_spec = jax.ShapeDtypeStruct((patches, rows), jnp.int32)
    w_spec = jax.ShapeDtypeStruct((K.WEIGHT_BITS, rows, cols), jnp.int32)

    def fn(x, planes):
        return (K.cim_matmul_graph(x, planes, adc_bits=3),)

    lowered = jax.jit(fn).lower(x_spec, w_spec)
    path = os.path.join(out_dir, "cim_matmul.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {"hlo": os.path.basename(path), "patches": patches, "rows": rows, "cols": cols, "adc_bits": 3}


def export_bitstats(out_dir: str, patches: int = 64, rows: int = 128) -> dict:
    x_spec = jax.ShapeDtypeStruct((patches, rows), jnp.int32)

    def fn(x):
        return (K.bitstats_graph(x),)

    lowered = jax.jit(fn).lower(x_spec)
    path = os.path.join(out_dir, "bitstats.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {"hlo": os.path.basename(path), "patches": patches, "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--hw", type=int, default=32, help="input resolution")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--nets", default="resnet18,vgg11")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict = {"schema": SCHEMA_VERSION, "models": {}, "kernels": {}}
    for net in args.nets.split(","):
        print(f"[aot] lowering {net} @ {args.hw}x{args.hw} …")
        manifest["models"][net] = export_model(net, args.hw, args.seed, args.out)
    print("[aot] lowering pallas cim_matmul kernel …")
    manifest["kernels"]["cim_matmul"] = export_cim_kernel(args.out)
    print("[aot] lowering pallas bitstats kernel …")
    manifest["kernels"]["bitstats"] = export_bitstats(args.out)

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
