"""L2: quantized ResNet18 / VGG11 forward passes in JAX.

These mirror the Rust graph builders (``dnn::resnet18`` / ``dnn::vgg11``)
exactly — same conv stack, same layer order (block conv1, conv2, then
projection downsample), same CHW im2col patch order — so the activation
statistics and golden outputs they produce line up one-to-one with the
Rust ``mapping::NetworkMap`` grids.

Arithmetic is true 8-bit CIM arithmetic: activations quantize to u8
(post-ReLU, affine, zero-point 0), weights to i8 (symmetric), every conv
is an im2col + *integer* matmul accumulated in i32 — bit-exact with what
the crossbar sub-arrays compute (the Pallas `cim_matmul` kernel and Rust
`xbar::SubArray` produce these very numbers; `test_model.py` pins the
identity). Floating point appears only between layers (dequantize →
pool/residual → requantize), standing in for the chip's digital vector
units (paper §IV).

The forward pass returns every conv layer's quantized u8 *input* — the
word-line data the simulator's traces are built from.

Weights are generated from a seed (He-init with per-channel lognormal
scale diversity; DESIGN.md §3) — there are no trained checkpoints in
this environment, and cycle counts depend only on activation statistics.
For AOT export the int8 weights travel as a single flat *parameter*
(``flat_weights``), keeping the HLO text free of megabyte constants; the
Rust runtime feeds the same buffer from ``artifacts/weights_<net>.bin``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

ACT_BITS = 8
ACT_MAX = (1 << ACT_BITS) - 1


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    in_ch: int
    out_ch: int
    k: int
    stride: int
    pad: int

    @property
    def rows(self) -> int:
        return self.k * self.k * self.in_ch


def _he_weights(rng: np.random.Generator, spec: ConvSpec, channel_sigma: float = 0.4):
    fan_in = spec.rows
    w = rng.normal(0.0, np.sqrt(2.0 / fan_in), size=(spec.out_ch, fan_in))
    # per-output-channel scale diversity → next layer's per-channel (and
    # hence per-block) bit-density spread (Fig 6)
    w *= np.exp(channel_sigma * rng.normal(size=(spec.out_ch, 1)))
    return w.astype(np.float32)


def _quantize_weights(w: np.ndarray) -> tuple[np.ndarray, float]:
    scale = float(np.abs(w).max()) / 127.0
    if scale == 0.0:
        scale = 1.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale


def im2col(x, k: int, stride: int, pad: int):
    """CHW patch lowering: ``x [C, H, W] -> [P, C*k*k]``, rows ordered
    (channel, ky, kx) — identical to Rust ``tensor::im2col_u8``."""
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            sl = xp[
                :,
                ky : ky + (oh - 1) * stride + 1 : stride,
                kx : kx + (ow - 1) * stride + 1 : stride,
            ]
            cols.append(sl)  # [C, OH, OW]
    patches = jnp.stack(cols, axis=1)  # [C, k*k, OH, OW]
    return patches.reshape(c * k * k, oh * ow).T, oh, ow


def quantize_act(x):
    """Affine u8 quantization of a non-negative float tensor; returns
    (q_u8, scale). Scale is computed in-graph (dynamic calibration)."""
    mx = jnp.maximum(jnp.max(x), 1e-6)
    scale = mx / ACT_MAX
    q = jnp.clip(jnp.round(x / scale), 0, ACT_MAX).astype(jnp.uint8)
    return q, scale


def qconv_apply(spec: ConvSpec, w_q, w_scale: float, x_float):
    """Quantize input → integer conv → dequantized float output.

    ``w_q``: i32 weight matrix ``[R, Cout]`` in crossbar row order.
    Returns ``(y_float [Cout, OH, OW], x_q [Cin, H, W] u8)`` where
    ``x_q`` is the crossbar's word-line view of this layer's input.
    """
    x_q, x_scale = quantize_act(x_float)
    patches, oh, ow = im2col(x_q.astype(jnp.int32), spec.k, spec.stride, spec.pad)
    acc = patches @ w_q  # exact i32, [P, Cout]
    y = acc.astype(jnp.float32) * (x_scale * w_scale)
    y = y.T.reshape(spec.out_ch, oh, ow)
    return y, x_q


def maxpool2(x):
    c, h, w = x.shape
    return x.reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))


@dataclasses.dataclass
class QModel:
    """A quantized network: conv specs + baked i8 weights + forward fn."""

    name: str
    hw: int
    num_classes: int
    specs: list[ConvSpec]
    conv_w: list[np.ndarray]  # int8 [R, Cout] each
    conv_scales: list[float]
    fc_w: np.ndarray  # int8 [in, out]
    fc_scale: float

    def forward(self, image, conv_w: Sequence, fc_w):
        """Pure forward given weight arrays (i32 jnp). Returns
        ``(acts tuple of u8, logits f32)``."""
        raise NotImplementedError  # set per-architecture below

    def apply(self, image):
        """Forward with the baked weights."""
        conv_w = [jnp.asarray(w, jnp.int32) for w in self.conv_w]
        fc_w = jnp.asarray(self.fc_w, jnp.int32)
        return self.forward(image, conv_w, fc_w)

    # --- flat-weight (AOT parameter) interface ---------------------------

    def flat_weights(self) -> np.ndarray:
        """All conv weights + fc concatenated as one i8 buffer."""
        parts = [w.reshape(-1) for w in self.conv_w] + [self.fc_w.reshape(-1)]
        return np.concatenate(parts).astype(np.int8)

    def weight_layout(self) -> list[dict]:
        """Per-tensor (name, offset, shape) for the manifest."""
        out = []
        off = 0
        for spec, w in zip(self.specs, self.conv_w):
            out.append({"name": spec.name, "offset": off, "shape": list(w.shape)})
            off += w.size
        out.append({"name": "fc", "offset": off, "shape": list(self.fc_w.shape)})
        return out

    def forward_flat(self, image, wflat_i8):
        """Forward where all weights arrive as one flat i8 parameter —
        the AOT-exported entry point."""
        wi = wflat_i8.astype(jnp.int32)
        conv_w = []
        off = 0
        for w in self.conv_w:
            n = w.size
            conv_w.append(wi[off : off + n].reshape(w.shape))
            off += n
        fc = wi[off : off + self.fc_w.size].reshape(self.fc_w.shape)
        return self.forward(image, conv_w, fc)


def _resnet_specs() -> list[ConvSpec]:
    specs: list[ConvSpec] = [ConvSpec("conv1", 3, 64, 7, 2, 3)]
    stage_ch = [64, 128, 256, 512]
    in_ch = 64
    for s, ch in enumerate(stage_ch):
        for b in range(2):
            stride = 2 if (s > 0 and b == 0) else 1
            tag = f"l{s + 1}b{b}"
            specs.append(ConvSpec(f"{tag}.conv1", in_ch, ch, 3, stride, 1))
            specs.append(ConvSpec(f"{tag}.conv2", ch, ch, 3, 1, 1))
            if stride != 1 or in_ch != ch:
                specs.append(ConvSpec(f"{tag}.downsample", in_ch, ch, 1, stride, 0))
            in_ch = ch
    return specs


def build_resnet18(hw: int = 32, num_classes: int = 10, seed: int = 0) -> QModel:
    """Mirror of Rust ``dnn::resnet18`` (20 conv layers + fc)."""
    assert hw % 32 == 0, "hw must be divisible by 32"
    rng = np.random.default_rng(seed)
    specs = _resnet_specs()
    qs = [_quantize_weights(_he_weights(rng, s)) for s in specs]
    conv_w = [q.T.copy() for q, _ in qs]
    conv_scales = [s for _, s in qs]
    fc_w, fc_scale = _quantize_weights(
        rng.normal(0.0, np.sqrt(2.0 / 512), size=(512, num_classes)).astype(np.float32)
    )
    m = QModel("resnet18", hw, num_classes, specs, conv_w, conv_scales, fc_w, fc_scale)
    idx = {s.name: i for i, s in enumerate(specs)}
    stage_ch = [64, 128, 256, 512]

    def forward(image, cw, fc):
        acts: list = [None] * len(specs)

        def run(name, x):
            i = idx[name]
            y, x_q = qconv_apply(specs[i], cw[i], conv_scales[i], x)
            acts[i] = x_q
            return y

        x = run("conv1", image)
        x = jnp.maximum(x, 0.0)
        x = maxpool2(x)
        in_c = 64
        for s, ch in enumerate(stage_ch):
            for b in range(2):
                stride = 2 if (s > 0 and b == 0) else 1
                tag = f"l{s + 1}b{b}"
                identity = x
                y = jnp.maximum(run(f"{tag}.conv1", x), 0.0)
                y = run(f"{tag}.conv2", y)
                if stride != 1 or in_c != ch:
                    identity = run(f"{tag}.downsample", x)
                x = jnp.maximum(y + identity, 0.0)
                in_c = ch
        x = x.mean(axis=(1, 2))  # GAP -> [512]
        x_q, x_scale = quantize_act(x)
        logits = (x_q.astype(jnp.int32) @ fc).astype(jnp.float32) * (x_scale * fc_scale)
        return tuple(acts), logits

    m.forward = forward  # type: ignore[method-assign]
    return m


def build_vgg11(hw: int = 32, num_classes: int = 10, seed: int = 1) -> QModel:
    """Mirror of Rust ``dnn::vgg11`` (8 conv layers + fc)."""
    assert hw % 32 == 0, "hw must be divisible by 32"
    rng = np.random.default_rng(seed)
    cfg = [(64, True), (128, True), (256, False), (256, True), (512, False), (512, True), (512, False), (512, True)]
    specs = []
    in_ch = 3
    for i, (ch, _pool) in enumerate(cfg):
        specs.append(ConvSpec(f"conv{i + 1}", in_ch, ch, 3, 1, 1))
        in_ch = ch
    qs = [_quantize_weights(_he_weights(rng, s)) for s in specs]
    conv_w = [q.T.copy() for q, _ in qs]
    conv_scales = [s for _, s in qs]
    fc_w, fc_scale = _quantize_weights(
        rng.normal(0.0, np.sqrt(2.0 / 512), size=(512, num_classes)).astype(np.float32)
    )
    m = QModel("vgg11", hw, num_classes, specs, conv_w, conv_scales, fc_w, fc_scale)

    def forward(image, cw, fc):
        acts = []
        x = image
        for i, (spec, (_, pool)) in enumerate(zip(specs, cfg)):
            y, x_q = qconv_apply(spec, cw[i], conv_scales[i], x)
            acts.append(x_q)
            x = jnp.maximum(y, 0.0)
            if pool:
                x = maxpool2(x)
        x = x.mean(axis=(1, 2))
        x_q, x_scale = quantize_act(x)
        logits = (x_q.astype(jnp.int32) @ fc).astype(jnp.float32) * (x_scale * fc_scale)
        return tuple(acts), logits

    m.forward = forward  # type: ignore[method-assign]
    return m


def build(name: str, hw: int, num_classes: int = 10, seed: int = 0) -> QModel:
    if name == "resnet18":
        return build_resnet18(hw, num_classes, seed)
    if name == "vgg11":
        return build_vgg11(hw, num_classes, seed)
    raise ValueError(f"unknown model '{name}'")


def synthetic_image(hw: int, seed: int = 0) -> np.ndarray:
    """Smoothed uniform 'natural' image in [0, 255], f32 [3, hw, hw] —
    matches the Rust synthetic generator's pixel statistics."""
    rng = np.random.default_rng(seed)
    img = rng.uniform(0, 255, size=(3, hw, hw)).astype(np.float32)
    # cheap spatial low-pass for patch-to-patch correlation
    img = (img + np.roll(img, 1, axis=1) + np.roll(img, 1, axis=2) + np.roll(img, (1, 1), (1, 2))) / 4.0
    return img.astype(np.float32)
